"""GNN architecture zoo — SchNet, GraphSAGE, MACE(-lite), GIN.

Message passing is implemented with ``jax.ops.segment_sum`` over an
(E, 2) edge-index array (JAX has no CSR sparse — scatter/segment ops ARE
the system here, per the assignment).  Three input regimes share the
same layer cores:

  * full-graph:   edge_index over all N nodes (full_graph_sm/ogb_products)
  * ELL blocks:   padded fanout samples from graphs/sampler (minibatch_lg)
  * molecules:    (B, M)-padded batches flattened into one disjoint graph

MACE adaptation (DESIGN §6): the real MACE contracts spherical-harmonic
irreps with Clebsch–Gordan tables; we build the equivalent *Cartesian*
equivariant features up to l=2 (vector and traceless rank-2 moments) and
take correlation-order-3 invariant contractions (ACE style).  Outputs are
E(3)-invariant — verified by the rotation-invariance test.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init

__all__ = ["GNNConfig", "init_gnn_params", "gnn_forward_full", "gnn_forward_blocks", "gnn_node_loss", "gnn_energy_loss"]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    kind: str = "gin"  # gin | sage | schnet | mace
    n_layers: int = 2
    d_hidden: int = 64
    d_in: int = 16
    n_classes: int = 8
    # schnet
    n_rbf: int = 300
    cutoff: float = 10.0
    # mace
    l_max: int = 2
    correlation: int = 3
    mace_n_rbf: int = 8
    # sage
    aggregator: str = "mean"
    dtype: Any = "float32"
    # §Perf B1: partition-parallel full-graph training with halo exchange
    partition_parallel: bool = False
    n_shards: int = 16
    boundary_frac: float = 0.05

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(ks[i], (dims[i], dims[i + 1])), "b": jnp.zeros((dims[i + 1],))}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"].astype(x.dtype) + l["b"].astype(x.dtype)
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def init_gnn_params(key, cfg: GNNConfig):
    ks = jax.random.split(key, cfg.n_layers + 3)
    H = cfg.d_hidden
    p: dict = {"encode": _mlp_init(ks[0], [cfg.d_in, H])}
    layers = []
    for i in range(cfg.n_layers):
        k = ks[1 + i]
        if cfg.kind == "gin":
            layers.append(
                {"mlp": _mlp_init(k, [H, H, H]), "eps": jnp.zeros(())}
            )
        elif cfg.kind == "sage":
            k1, k2 = jax.random.split(k)
            layers.append({"w_self": dense_init(k1, (H, H)), "w_nbr": dense_init(k2, (H, H)), "b": jnp.zeros((H,))})
        elif cfg.kind == "schnet":
            k1, k2, k3 = jax.random.split(k, 3)
            layers.append(
                {
                    "filter": _mlp_init(k1, [cfg.n_rbf, H, H]),
                    "dense1": dense_init(k2, (H, H)),
                    "dense2": dense_init(k3, (H, H)),
                    "b1": jnp.zeros((H,)),
                    "b2": jnp.zeros((H,)),
                }
            )
        elif cfg.kind == "mace":
            k1, k2 = jax.random.split(k)
            n_inv = 5  # A0, |A1|², A2:A2, A1·A2·A1, A0³ (correlation-3 set)
            layers.append(
                {
                    "radial": _mlp_init(k1, [cfg.mace_n_rbf, H, 3 * H]),  # per-l channel weights
                    "mix": _mlp_init(k2, [n_inv * H, H, H]),
                }
            )
        else:
            raise ValueError(cfg.kind)
    p["layers"] = layers
    p["readout"] = _mlp_init(ks[-1], [H, cfg.n_classes])
    return p


# ----------------------------------------------------------- basis fns ----


def _rbf(d, n_rbf, cutoff):
    """Gaussian radial basis (SchNet) with cosine cutoff envelope."""
    centers = jnp.linspace(0.0, cutoff, n_rbf, dtype=d.dtype)
    gamma = n_rbf / cutoff
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cutoff, 0, 1)) + 1.0)
    return jnp.exp(-gamma * (d[..., None] - centers) ** 2) * env[..., None]


def _bessel(d, n_rbf, cutoff):
    """Bessel radial basis (MACE/NequIP)."""
    n = jnp.arange(1, n_rbf + 1, dtype=d.dtype)
    x = jnp.clip(d, 1e-6, None)
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cutoff, 0, 1)) + 1.0)
    return (jnp.sin(n * jnp.pi * x[..., None] / cutoff) / x[..., None]) * env[..., None]


def _ssp(x):  # shifted softplus (SchNet activation)
    return jax.nn.softplus(x) - np.log(2.0)


# ------------------------------------------------------------- layers -----


def _agg(msg, dst, n_nodes, how="sum"):
    s = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    if how == "sum":
        return s
    cnt = jax.ops.segment_sum(jnp.ones((msg.shape[0], 1), msg.dtype), dst, num_segments=n_nodes)
    if how == "mean":
        return s / jnp.maximum(cnt, 1.0)
    raise ValueError(how)


def _gin_layer(p, h, src, dst, n_nodes, cfg):
    nbr = _agg(h[src], dst, n_nodes, "sum")
    return _mlp_apply(p["mlp"], (1.0 + p["eps"]) * h + nbr)


def _sage_layer(p, h, src, dst, n_nodes, cfg):
    nbr = _agg(h[src], dst, n_nodes, cfg.aggregator)
    out = h @ p["w_self"].astype(h.dtype) + nbr @ p["w_nbr"].astype(h.dtype) + p["b"].astype(h.dtype)
    return jax.nn.relu(out)


def _schnet_layer(p, h, src, dst, n_nodes, cfg, dist):
    w = _mlp_apply(p["filter"], _rbf(dist, cfg.n_rbf, cfg.cutoff).astype(h.dtype), act=_ssp, final_act=True)
    msg = h[src] * w  # cfconv: continuous filter × neighbor features
    agg = _agg(msg, dst, n_nodes, "sum")
    out = _ssp(agg @ p["dense1"].astype(h.dtype) + p["b1"].astype(h.dtype))
    return h + out @ p["dense2"].astype(h.dtype) + p["b2"].astype(h.dtype)


def _mace_layer(p, h, src, dst, n_nodes, cfg, vec, dist):
    """Cartesian ACE layer, l ≤ 2, correlation order 3 (see module doc)."""
    H = h.shape[-1]
    rhat = vec / jnp.maximum(dist[:, None], 1e-6)
    radial = _mlp_apply(p["radial"], _bessel(dist, cfg.mace_n_rbf, cfg.cutoff).astype(h.dtype))
    R0, R1, R2 = radial[:, :H], radial[:, H : 2 * H], radial[:, 2 * H :]
    hj = h[src]
    # l = 0, 1, 2 equivariant moments
    A0 = _agg(R0 * hj, dst, n_nodes, "sum")  # (N, H)
    m1 = (R1 * hj)[:, None, :] * rhat[:, :, None]  # (E, 3, H)
    A1 = jax.ops.segment_sum(m1, dst, num_segments=n_nodes)  # (N, 3, H)
    outer = rhat[:, :, None] * rhat[:, None, :] - jnp.eye(3, dtype=h.dtype) / 3.0
    m2 = (R2 * hj)[:, None, None, :] * outer[..., None]  # (E, 3, 3, H)
    A2 = jax.ops.segment_sum(m2, dst, num_segments=n_nodes)  # (N, 3, 3, H)
    # invariant contractions, correlation order up to 3
    B1 = jnp.sum(A1 * A1, axis=1)  # (N, H)
    B2 = jnp.einsum("nabh,nabh->nh", A2, A2)
    B3 = jnp.einsum("nah,nabh,nbh->nh", A1, A2, A1)  # order-3 coupling
    B4 = A0 * A0 * A0
    inv = jnp.concatenate([A0, B1, B2, B3, B4], axis=-1)
    return h + _mlp_apply(p["mix"], inv)


# ------------------------------------------------------------- drivers ----


def gnn_forward_full(params, cfg: GNNConfig, node_feat, edge_index, positions=None, n_nodes=None):
    """Full-graph forward.  node_feat (N, d_in); edge_index (E, 2) directed.

    Geometric models (schnet/mace) require ``positions`` (N, 3).
    """
    dtype = cfg.compute_dtype
    h = _mlp_apply(params["encode"], node_feat.astype(dtype))
    n = n_nodes or node_feat.shape[0]
    src, dst = edge_index[:, 0], edge_index[:, 1]
    vec = dist = None
    if cfg.kind in ("schnet", "mace"):
        assert positions is not None
        vec = (positions[src] - positions[dst]).astype(dtype)
        dist = jnp.linalg.norm(vec, axis=-1)
    for p in params["layers"]:
        if cfg.kind == "gin":
            h = _gin_layer(p, h, src, dst, n, cfg)
        elif cfg.kind == "sage":
            h = _sage_layer(p, h, src, dst, n, cfg)
        elif cfg.kind == "schnet":
            h = _schnet_layer(p, h, src, dst, n, cfg, dist)
        elif cfg.kind == "mace":
            h = _mace_layer(p, h, src, dst, n, cfg, vec, dist)
    return _mlp_apply(params["readout"], h)  # (N, n_classes)


def gnn_forward_blocks(params, cfg: GNNConfig, feats, blocks):
    """Sampled-minibatch forward over ELL blocks (GraphSAGE regime).

    feats: (N_outer, d_in) features of the outermost layer's vertex set;
    blocks: list over layers, outermost first, each dict with
      nbr_index (n_dst, fanout) int32 and mask (n_dst, fanout) bool,
      dst_index (n_dst,) — rows of the src set that are the dst vertices.
    """
    dtype = cfg.compute_dtype
    h = _mlp_apply(params["encode"], feats.astype(dtype))
    for p, blk in zip(params["layers"], blocks):
        nbr = h[blk["nbr_index"]]  # (n_dst, fanout, H) ELL gather
        mask = blk["mask"][..., None].astype(dtype)
        s = jnp.sum(nbr * mask, axis=1)
        if cfg.kind == "sage" and cfg.aggregator == "mean":
            agg = s / jnp.maximum(mask.sum(1), 1.0)
        else:
            agg = s
        h_dst = h[blk["dst_index"]]
        if cfg.kind == "gin":
            h = _mlp_apply(p["mlp"], (1.0 + p["eps"]) * h_dst + agg)
        else:  # sage-style update works for every kind in sampled regime
            w_self = p.get("w_self")
            if w_self is None:  # schnet/mace sampled fallback: dense mix
                h = jax.nn.relu(h_dst + agg)
            else:
                h = jax.nn.relu(
                    h_dst @ p["w_self"].astype(dtype) + agg @ p["w_nbr"].astype(dtype) + p["b"].astype(dtype)
                )
    return _mlp_apply(params["readout"], h)


# --------------------------------------------------------------- losses ----


def gnn_node_loss(params, cfg: GNNConfig, batch):
    """Node-classification CE (full-graph shapes)."""
    logits = gnn_forward_full(
        params, cfg, batch["node_feat"], batch["edge_index"], batch.get("positions")
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    mask = batch.get("train_mask")
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0), {}
    return jnp.mean(nll), {}


def gnn_energy_loss(params, cfg: GNNConfig, batch):
    """Molecular energy regression (molecule shapes): batched graphs are
    flattened to one disjoint graph; per-graph readout = masked segment sum."""
    out = gnn_forward_full(
        params,
        cfg,
        batch["node_feat"],
        batch["edge_index"],
        batch.get("positions"),
    )  # (B·M, n_out)
    graph_id = batch["graph_id"]
    n_graphs = batch["energy"].shape[0]
    node_e = out[:, 0] * batch["node_mask"]
    energy = jax.ops.segment_sum(node_e, graph_id, num_segments=n_graphs)
    loss = jnp.mean((energy - batch["energy"]) ** 2)
    return loss, {"energy_mae": jnp.mean(jnp.abs(energy - batch["energy"]))}
