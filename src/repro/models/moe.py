"""Mixture-of-Experts block with expert parallelism (EP).

Token-choice top-k routing with per-shard capacity, GShard-style dropping.
Expert weights are sharded over the mesh ``model`` axis; the block runs
under ``shard_map``: every model shard sees the (data-sharded) tokens,
dispatches the subset routed to *its* experts into an (E_loc, C, D)
buffer via scatter, runs the expert FFNs as one batched GEMM, scatters
results back, and a single ``psum`` over ``model`` combines expert
contributions (equivalent bytes to the a2a pair, one collective — see
DESIGN §5 / EXPERIMENTS §Perf for the measured trade).

Shared experts (DeepSeek-style) are a dense SwiGLU applied to all tokens.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init

__all__ = ["MoEConfig", "init_moe_params", "moe_block"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 512
    n_shared: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True  # renormalize top-k gate weights to sum 1
    fsdp: bool = False  # expert weights extra-sharded over 'data' (ZeRO-3)


def init_moe_params(key, d_model: int, mcfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    E, F = mcfg.n_experts, mcfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), dtype=jnp.float32),
        "w1": dense_init(ks[1], (E, d_model, F), dtype=dtype),
        "w3": dense_init(ks[2], (E, d_model, F), dtype=dtype),
        "w2": dense_init(ks[3], (E, F, d_model), dtype=dtype),
    }
    if mcfg.n_shared:
        Fs = mcfg.n_shared * F
        p["shared_w1"] = dense_init(ks[4], (d_model, Fs), dtype=dtype)
        p["shared_w3"] = dense_init(ks[5], (d_model, Fs), dtype=dtype)
        p["shared_w2"] = dense_init(ks[6], (Fs, d_model), dtype=dtype)
    return p


def _route(x, router_w, mcfg: MoEConfig):
    """Top-k routing → (topk_idx, topk_weight, aux_loss)."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, mcfg.top_k)  # (T, K)
    if mcfg.norm_topk:
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    E = gates.shape[-1]
    me = jnp.mean(gates, axis=0)
    one_hot_top1 = jax.nn.one_hot(topi[:, 0], E)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce)
    return topi, topv, aux


def _dispatch_compute(x, router, w1, w3, w2, mcfg: MoEConfig, e_start, dtype):
    """Local expert compute.  x: (T_loc, D) tokens visible to this shard.

    ``w1/w3/w2`` are the *local* expert slices (E_loc leading dim); the
    router is the full (D, E) table.  Returns the partial output (T_loc, D)
    of experts [e_start, e_start + E_loc); caller psums over 'model'.
    """
    T, D = x.shape
    K = mcfg.top_k
    e_local = w1.shape[0]
    topi, topv, aux = _route(x, router, mcfg)
    cap = max(int(T * K / mcfg.n_experts * mcfg.capacity_factor), 4)

    flat_e = topi.reshape(-1)  # (T·K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = topv.reshape(-1)
    # rank within expert: sort by expert id, rank = index − segment start
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    st = flat_t[order]
    sw = flat_w[order]
    rank = jnp.arange(se.shape[0]) - jnp.searchsorted(se, se, side="left")
    keep = rank < cap  # capacity dropping (GShard)
    local = keep & (se >= e_start) & (se < e_start + e_local)
    e_idx = jnp.where(local, se - e_start, 0)
    slot = jnp.where(local, rank, cap - 1)

    gathered = jnp.where(local[:, None], x[st], 0.0).astype(dtype)
    buf = jnp.zeros((e_local, cap, D), dtype)
    buf = buf.at[e_idx, slot].add(gathered)  # (E_loc, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1.astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w3.astype(dtype))
    y = jnp.einsum("ecf,efd->ecd", h, w2.astype(dtype))

    back = y[e_idx, slot] * jnp.where(local, sw, 0.0).astype(dtype)[:, None]
    out = jnp.zeros((T, D), dtype).at[st].add(back)
    return out, aux


def moe_block(x2d, params, mcfg: MoEConfig, mesh=None):
    """x2d: (T, D) tokens (T sharded over data axes when mesh active)."""
    dtype = x2d.dtype

    if mesh is not None and "model" in mesh.axis_names and mesh.shape["model"] > 1:
        n_shards = mesh.shape["model"]
        assert mcfg.n_experts % n_shards == 0, "E must divide model shards"
        e_local = mcfg.n_experts // n_shards
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        from jax.sharding import PartitionSpec as P

        fsdp = mcfg.fsdp and "data" in mesh.axis_names and mesh.shape["data"] > 1
        data_axes_size = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                data_axes_size *= mesh.shape[a]
        # tiny token counts (e.g. batch-1 decode) can't shard over data —
        # replicate tokens instead (experts stay model-sharded)
        tokens_spec_axes = None if x2d.shape[0] % data_axes_size else True

        def shard_fn(x, router, w1, w3, w2):
            ax = jax.lax.axis_index("model")
            if fsdp:
                # ZeRO-3: expert weights arrive sharded over 'data' on their
                # hidden dim; gather just-in-time (cast first to halve bytes)
                w1 = jax.lax.all_gather(w1.astype(dtype), "data", axis=1, tiled=True)
                w3 = jax.lax.all_gather(w3.astype(dtype), "data", axis=1, tiled=True)
                w2 = jax.lax.all_gather(w2.astype(dtype), "data", axis=1, tiled=True)
            out, aux = _dispatch_compute(x, router, w1, w3, w2, mcfg, ax * e_local, dtype)
            out = jax.lax.psum(out, "model")
            aux = jax.lax.psum(aux, "model") / n_shards
            return out, aux

        wspec = P("model", "data", None) if fsdp else P("model")
        xspec = P(data_axes, None) if (data_axes and tokens_spec_axes) else P(None, None)
        # NOTE: expert weights enter pre-sharded over 'model'; tokens over data.
        out, aux = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(xspec, P(), wspec, wspec, wspec),
            out_specs=(xspec, P()),
            check_vma=False,
        )(x2d, params["router"], params["w1"], params["w3"], params["w2"])
    else:
        out, aux = _dispatch_compute(
            x2d, params["router"], params["w1"], params["w3"], params["w2"], mcfg, 0, dtype
        )

    if mcfg.n_shared:
        h = jax.nn.silu(x2d @ params["shared_w1"].astype(dtype))
        h = h * (x2d @ params["shared_w3"].astype(dtype))
        out = out + h @ params["shared_w2"].astype(dtype)
    return out, aux
