"""Shared neural building blocks (pure-JAX, pytree params, no flax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "rms_norm",
    "rope_freqs",
    "apply_rope",
    "cross_entropy_loss",
    "count_params",
]


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * s).astype(dtype)


def rms_norm(x, gamma, eps: float = 1e-6, compute_dtype_elementwise: bool = True):
    """RMSNorm with f32 *reduction* only (§Perf A4): the variance sum runs in
    f32 for stability, but the normalize/scale elementwise chain stays in the
    compute dtype — halves the normalization's HBM traffic in bf16 models
    (backward no longer materializes f32 copies of the residual stream)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    if compute_dtype_elementwise:
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * (1.0 + gamma.astype(x.dtype))
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, dh) rotary on the last dim; positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token cross-entropy; logits (..., V), labels (...,) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))
