"""Invariant auditor for a live engine — offline CLI or server admin call.

Three families of checks, per (sampled) partition:

* **index reconstruction** — the packed forest's levels, quantized
  sidecars, and GNN-PGE group bounds must equal a from-scratch
  ``build_index``/``attach_groups`` over the partition's own leaf
  payload (bit rot in an MBR, a group bound, or a sidecar can silently
  widen or *narrow* pruning — narrowing breaks no-false-dismissal);
* **delta bookkeeping** — the memoized tombstone count must match the
  mask, buffer arrays must agree on row count;
* **tombstone/delta consistency** — live rows (``main ∪ delta −
  tombstones``) must equal a fresh ``enumerate_paths`` of the *current*
  graph over the partition's members, with the two sides disjoint —
  the exact soundness invariant of the delta decomposition.

``scrub_engine`` returns a report dict; ``python -m
repro.durability.scrub --dir <durability-dir>`` recovers an engine from
a durability directory (config rides in the snapshot) and audits it.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from ..core.grouping import attach_groups
from ..core.index import build_index
from ..core.paths import enumerate_paths
from ..obs import REGISTRY

__all__ = ["scrub_engine", "main"]

_M_RUNS = REGISTRY.counter("gnnpe_scrub_runs_total", "scrub passes", labels=("outcome",))
_M_VIOLATIONS = REGISTRY.counter("gnnpe_scrub_violations_total", "scrub violations found")


def _check_index(mi: int, index, labels, out: list) -> None:
    rebuilt = build_index(
        index.paths,
        index.emb,
        index.emb0,
        index.emb_multi,
        block_size=index.block_size,
        fanout=index.fanout,
        quantize=index.emb_q is not None,
        path_labels=labels[index.paths] if index.emb_q is not None and index.n_paths else None,
    )
    if len(rebuilt.levels) != len(index.levels):
        out.append({"partition": mi, "check": "levels", "detail": "level count differs"})
        return
    for li, (a, b) in enumerate(zip(index.levels, rebuilt.levels)):
        for k in ("mbr", "mbr0", "mbr_multi"):
            if not np.array_equal(a[k], b[k]):
                out.append(
                    {"partition": mi, "check": "mbr",
                     "detail": f"level {li} {k} differs from recomputation"}
                )
    for k in ("emb_q", "label_hash"):
        a, b = getattr(index, k), getattr(rebuilt, k)
        if (a is None) != (b is None) or (a is not None and not np.array_equal(a, b)):
            out.append({"partition": mi, "check": "sidecar", "detail": f"{k} differs"})
    if index.groups is not None:
        attach_groups(rebuilt, index.groups.group_size)
        for k in ("group_start", "mbr_hi", "mbr0", "block_group_start"):
            if not np.array_equal(getattr(index.groups, k), getattr(rebuilt.groups, k)):
                out.append(
                    {"partition": mi, "check": "groups", "detail": f"groups.{k} differs"}
                )


def _check_delta(mi: int, dp, out: list) -> None:
    if int(dp.tombstone.sum()) != dp.n_tomb:
        out.append(
            {"partition": mi, "check": "tombstone",
             "detail": f"n_tomb {dp.n_tomb} != mask sum {int(dp.tombstone.sum())}"}
        )
    B = dp.n_rows
    for k in ("emb", "emb0"):
        if getattr(dp, k).shape[0] != B:
            out.append(
                {"partition": mi, "check": "delta",
                 "detail": f"buffer {k} rows != paths rows"}
            )
    if dp.emb_multi.shape[1] != B:
        out.append({"partition": mi, "check": "delta", "detail": "emb_multi rows != paths rows"})


def _check_enumeration(mi: int, engine, model, dp, out: list) -> None:
    live = model.index.paths[~dp.tombstone] if model.index.n_paths else model.index.paths
    main_set = {tuple(int(v) for v in r) for r in live}
    delta_set = {tuple(int(v) for v in r) for r in dp.paths}
    if main_set & delta_set:
        out.append(
            {"partition": mi, "check": "enumerate",
             "detail": f"{len(main_set & delta_set)} paths in both main and delta"}
        )
    expect = enumerate_paths(
        engine.graph, model.members.astype(np.int32), engine.cfg.path_length
    )
    expect_set = {tuple(int(v) for v in r) for r in expect}
    got = main_set | delta_set
    if got != expect_set:
        out.append(
            {"partition": mi, "check": "enumerate",
             "detail": f"live view has {len(got - expect_set)} phantom / "
                       f"{len(expect_set - got)} missing paths vs fresh enumerate"}
        )


def scrub_engine(engine, sample: int | None = None, seed: int = 0) -> dict:
    """Audit ``engine`` → report dict.

    ``sample``: audit only that many randomly chosen partitions (the
    enumerate check re-enumerates a partition's paths, so full scrubs on
    big graphs are an offline affair); ``None`` audits all of them.
    """
    t0 = time.perf_counter()
    n = len(engine.models)
    picks = list(range(n))
    if sample is not None and sample < n:
        picks = sorted(np.random.default_rng(seed).choice(n, size=sample, replace=False))
    violations: list = []
    for mi in picks:
        model = engine.models[mi]
        dp = engine.delta.parts[mi]
        _check_index(mi, model.index, engine.graph.labels, violations)
        _check_delta(mi, dp, violations)
        _check_enumeration(mi, engine, model, dp, violations)
    report = {
        "ok": not violations,
        "violations": violations,
        "partitions_checked": [int(i) for i in picks],
        "epoch": int(engine.epoch),
        "scrub_s": time.perf_counter() - t0,
    }
    _M_RUNS.labels(outcome="ok" if report["ok"] else "violations").inc()
    if violations:
        _M_VIOLATIONS.inc(len(violations))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="offline scrub of a durability directory")
    ap.add_argument("--dir", required=True, help="DurabilityConfig.directory")
    ap.add_argument("--sample", type=int, default=None, help="partitions to sample")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    from .recovery import recover_engine_from_dir

    engine, info = recover_engine_from_dir(args.dir)
    report = scrub_engine(engine, sample=args.sample, seed=args.seed)
    report["recovered_epoch"] = info["epoch"]
    print(json.dumps(report, indent=2))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
