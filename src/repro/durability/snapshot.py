"""Periodic engine snapshots through the verified ``CheckpointManager``.

A snapshot is the engine's *complete* live state flattened to exact
host arrays — graph CSR, partitioning, GNN params, node embeddings,
per-partition ``PackedIndex`` payloads, and the full delta state
(tombstones + unsorted buffers) — plus a JSON meta leaf carrying the
engine config, epoch, fingerprint, and the serving tier's standing
subscriptions.  Restore reconstructs the packed forests by running the
saved (already-sorted) leaf payloads back through ``build_index`` — the
stable lexsort is the identity on sorted input, so the rebuilt index is
bit-identical (verified at restore; the GNN-PGE group sidecar is
serialized directly).  Steps are keyed by delta epoch; the manifest +
digest verification and newest-*valid*-step fallback all come from
``dist/checkpoint.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time

import jax.numpy as jnp
import numpy as np

from ..core.delta import DeltaIndex
from ..core.engine import GnnPeConfig, GnnPeEngine, PartitionModel
from ..core.index import PackedGroupIndex, build_index
from ..core.training import TrainConfig
from ..dist.checkpoint import CheckpointManager, CorruptCheckpointError
from ..graphs.graph import Graph
from ..graphs.partition import Partitioning
from ..obs import REGISTRY

__all__ = [
    "SnapshotStore",
    "engine_state",
    "restore_engine",
    "engine_fingerprint",
    "SnapshotIntegrityError",
]

_META_KEY = "__snap_meta__"
_FORMAT = 1

_M_SNAP_S = REGISTRY.histogram("gnnpe_snapshot_seconds", "engine snapshot wall time")
_M_SNAP_BYTES = REGISTRY.gauge("gnnpe_snapshot_bytes", "array bytes in the last snapshot")
_M_SNAPSHOTS = REGISTRY.counter("gnnpe_snapshot_total", "engine snapshots written")


class SnapshotIntegrityError(RuntimeError):
    """Restored state failed a self-check (index reconstruction drifted)."""


def _jsonable(x):
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    return x


# ---------------------------------------------------------------- flatten --


def engine_state(engine: GnnPeEngine, subscriptions: dict | None = None):
    """Flatten a built engine → ``(meta, {key: np.ndarray})``.

    ``subscriptions``: optional ``{sub_id: (query_graph, tenant)}`` live
    standing-query table — snapshotted alongside so WAL segments older
    than the snapshot can be pruned without losing registrations.
    """
    g = engine.graph
    arrays: dict[str, np.ndarray] = {
        "graph/offsets": np.asarray(g.offsets, np.int64),
        "graph/nbrs": np.asarray(g.nbrs, np.int32),
        "graph/labels": np.asarray(g.labels, np.int32),
        "part/assignment": np.asarray(engine.partitioning.assignment, np.int32),
        "label_perms": np.asarray(engine.label_perms, np.int64),
        "plp": np.asarray(engine._part_leaf_pairs, np.int64),
        "ppr": np.asarray(engine._part_probe_rows, np.int64),
    }
    models_meta = []
    for i, m in enumerate(engine.models):
        p = f"m{i}/"
        arrays[p + "members"] = np.asarray(m.members, np.int32)
        arrays[p + "vertex_set"] = np.asarray(m.vertex_set)
        arrays[p + "node_emb"] = np.asarray(m.node_emb, np.float32)
        arrays[p + "node_emb0"] = np.asarray(m.node_emb0, np.float32)
        arrays[p + "node_emb_multi"] = np.asarray(m.node_emb_multi, np.float32)
        arrays[p + "fbv"] = np.asarray(m.fallback_vids, np.int64)
        for j, fb in enumerate(m.fallback_vids_multi):
            arrays[p + f"fbm{j}"] = np.asarray(fb, np.int64)
        for k, v in m.params.items():
            arrays[p + f"param/{k}"] = np.asarray(v)
        for j, mp in enumerate(m.multi_params):
            for k, v in mp.items():
                arrays[p + f"mparam{j}/{k}"] = np.asarray(v)
        ix = m.index
        arrays[p + "ix/paths"] = np.asarray(ix.paths, np.int32)
        arrays[p + "ix/emb"] = np.asarray(ix.emb, np.float32)
        arrays[p + "ix/emb0"] = np.asarray(ix.emb0, np.float32)
        arrays[p + "ix/emb_multi"] = np.asarray(ix.emb_multi, np.float32)
        if ix.groups is not None:
            arrays[p + "gx/group_start"] = np.asarray(ix.groups.group_start, np.int64)
            arrays[p + "gx/mbr_hi"] = np.asarray(ix.groups.mbr_hi)
            arrays[p + "gx/mbr0"] = np.asarray(ix.groups.mbr0)
            arrays[p + "gx/block_group_start"] = np.asarray(
                ix.groups.block_group_start, np.int64
            )
        dp = engine.delta.parts[i]
        arrays[f"d{i}/tombstone"] = np.asarray(dp.tombstone, bool)
        arrays[f"d{i}/paths"] = np.asarray(dp.paths, np.int32)
        arrays[f"d{i}/emb"] = np.asarray(dp.emb, np.float32)
        arrays[f"d{i}/emb0"] = np.asarray(dp.emb0, np.float32)
        arrays[f"d{i}/emb_multi"] = np.asarray(dp.emb_multi, np.float32)
        if dp.emb_q is not None:
            arrays[f"d{i}/emb_q"] = np.asarray(dp.emb_q, np.int8)
        if dp.label_hash is not None:
            arrays[f"d{i}/label_hash"] = np.asarray(dp.label_hash, np.int64)
        models_meta.append(
            {
                "part_id": int(m.part_id),
                "train_epochs": int(m.train_epochs),
                "n_fallback": int(m.n_fallback),
                "n_multi": len(m.multi_params),
                "param_keys": sorted(m.params.keys()),
                "mparam_keys": [sorted(mp.keys()) for mp in m.multi_params],
                "block_size": int(ix.block_size),
                "fanout": int(ix.fanout),
                "quantize": ix.emb_q is not None,
                "group_size": int(ix.groups.group_size) if ix.groups is not None else None,
                "n_tomb": int(dp.n_tomb),
                "version": int(dp.version),
            }
        )
    subs_meta = []
    for sid in sorted(subscriptions or {}):
        q, tenant = subscriptions[sid]
        subs_meta.append({"id": int(sid), "tenant": str(tenant)})
        arrays[f"sub{sid}/offsets"] = np.asarray(q.offsets, np.int64)
        arrays[f"sub{sid}/nbrs"] = np.asarray(q.nbrs, np.int32)
        arrays[f"sub{sid}/labels"] = np.asarray(q.labels, np.int32)
    meta = {
        "format": _FORMAT,
        "config": _jsonable(dataclasses.asdict(engine.cfg)),
        "epoch": int(engine.epoch),
        "n_labels": int(engine.n_labels),
        "fingerprint": engine._emb_fingerprint.hex(),
        "models": models_meta,
        "delta_epoch": int(engine.delta.epoch),
        "n_compactions": int(engine.delta.n_compactions),
        "pending_compaction": sorted(int(i) for i in engine._pending_compaction),
        "offline_stats": _jsonable(engine.offline_stats),
        "subscriptions": subs_meta,
    }
    return meta, arrays


def _config_from_dict(d: dict) -> GnnPeConfig:
    d = dict(d)
    train = d.pop("train", {})
    return GnnPeConfig(train=TrainConfig(**train), **d)


def restore_engine(arrays: dict) -> tuple[GnnPeEngine, dict]:
    """Rebuild a live engine from a snapshot's array dict → ``(engine, meta)``.

    Self-contained: the config rides in the meta leaf, so recovery needs
    nothing but the durability directory.
    """
    meta = json.loads(str(arrays[_META_KEY]))
    cfg = _config_from_dict(meta["config"])
    eng = GnnPeEngine(cfg)
    g = Graph(
        offsets=np.asarray(arrays["graph/offsets"], np.int64),
        nbrs=np.asarray(arrays["graph/nbrs"], np.int32),
        labels=np.asarray(arrays["graph/labels"], np.int32),
    )
    eng.graph = g
    eng.n_labels = int(meta["n_labels"])
    eng.partitioning = Partitioning(
        assignment=np.asarray(arrays["part/assignment"], np.int32),
        n_parts=len(meta["models"]),
    )
    eng.label_perms = np.asarray(arrays["label_perms"], np.int64)
    eng.models = []
    indexes = []
    for i, mm in enumerate(meta["models"]):
        p = f"m{i}/"
        paths = np.asarray(arrays[p + "ix/paths"], np.int32)
        emb = np.asarray(arrays[p + "ix/emb"], np.float32)
        emb0 = np.asarray(arrays[p + "ix/emb0"], np.float32)
        emb_multi = np.asarray(arrays[p + "ix/emb_multi"], np.float32)
        index = build_index(
            paths,
            emb,
            emb0,
            emb_multi,
            block_size=mm["block_size"],
            fanout=mm["fanout"],
            quantize=mm["quantize"],
            path_labels=g.labels[paths] if mm["quantize"] and paths.size else None,
        )
        # the saved payload is in sorted order, so the stable lexsort must
        # be the identity — anything else means the reconstruction drifted
        if not (
            np.array_equal(index.paths, paths)
            and np.array_equal(index.emb, emb)
            and np.array_equal(index.emb0, emb0)
            and np.array_equal(index.emb_multi, emb_multi)
        ):
            raise SnapshotIntegrityError(
                f"partition {i}: index reconstruction is not bit-identical"
            )
        if mm["group_size"] is not None:
            index.groups = PackedGroupIndex(
                group_start=np.asarray(arrays[p + "gx/group_start"], np.int64),
                mbr_hi=np.asarray(arrays[p + "gx/mbr_hi"]),
                mbr0=np.asarray(arrays[p + "gx/mbr0"]),
                block_group_start=np.asarray(arrays[p + "gx/block_group_start"], np.int64),
                group_size=int(mm["group_size"]),
            )
        indexes.append(index)
        eng.models.append(
            PartitionModel(
                members=np.asarray(arrays[p + "members"], np.int32),
                vertex_set=np.asarray(arrays[p + "vertex_set"]),
                params={k: jnp.asarray(arrays[p + f"param/{k}"]) for k in mm["param_keys"]},
                multi_params=[
                    {k: jnp.asarray(arrays[p + f"mparam{j}/{k}"]) for k in keys}
                    for j, keys in enumerate(mm["mparam_keys"])
                ],
                label_perms=eng.label_perms,
                node_emb=np.asarray(arrays[p + "node_emb"], np.float32),
                node_emb0=np.asarray(arrays[p + "node_emb0"], np.float32),
                node_emb_multi=np.asarray(arrays[p + "node_emb_multi"], np.float32),
                index=index,
                train_epochs=int(mm["train_epochs"]),
                n_fallback=int(mm["n_fallback"]),
                part_id=int(mm["part_id"]),
                fallback_vids=np.asarray(arrays[p + "fbv"], np.int64),
                fallback_vids_multi=[
                    np.asarray(arrays[p + f"fbm{j}"], np.int64)
                    for j in range(mm["n_multi"])
                ],
            )
        )
    eng.delta = DeltaIndex(indexes)
    for i, mm in enumerate(meta["models"]):
        dp = eng.delta.parts[i]
        # copy: the engine ORs into this mask in place (tombstone_touched),
        # and the source array may be shared (in-memory clone) or read-only
        # (npz-backed) — either way aliasing it would corrupt the donor
        dp.tombstone = np.array(arrays[f"d{i}/tombstone"], bool, copy=True)
        dp.paths = np.asarray(arrays[f"d{i}/paths"], np.int32)
        dp.emb = np.asarray(arrays[f"d{i}/emb"], np.float32)
        dp.emb0 = np.asarray(arrays[f"d{i}/emb0"], np.float32)
        dp.emb_multi = np.asarray(arrays[f"d{i}/emb_multi"], np.float32)
        dp.emb_q = (
            np.asarray(arrays[f"d{i}/emb_q"], np.int8) if f"d{i}/emb_q" in arrays else None
        )
        dp.label_hash = (
            np.asarray(arrays[f"d{i}/label_hash"], np.int64)
            if f"d{i}/label_hash" in arrays
            else None
        )
        dp.n_tomb = int(mm["n_tomb"])
        dp.version = int(mm["version"])
    eng.delta.epoch = int(meta["delta_epoch"])
    eng.delta.n_compactions = int(meta["n_compactions"])
    eng.epoch = int(meta["epoch"])
    eng._emb_fingerprint = bytes.fromhex(meta["fingerprint"])
    eng._pending_compaction = set(meta["pending_compaction"])
    eng.offline_stats = meta["offline_stats"]
    # copied for the same reason as the tombstone mask: probe telemetry
    # accumulates into these with in-place +=
    eng._part_leaf_pairs = np.array(arrays["plp"], np.int64, copy=True)
    eng._part_probe_rows = np.array(arrays["ppr"], np.int64, copy=True)
    return eng, meta


def restore_subscriptions(meta: dict, arrays: dict) -> dict:
    """``{sub_id: (query_graph, tenant)}`` from a snapshot's state."""
    out = {}
    for s in meta.get("subscriptions", []):
        sid = int(s["id"])
        out[sid] = (
            Graph(
                offsets=np.asarray(arrays[f"sub{sid}/offsets"], np.int64),
                nbrs=np.asarray(arrays[f"sub{sid}/nbrs"], np.int32),
                labels=np.asarray(arrays[f"sub{sid}/labels"], np.int32),
            ),
            s["tenant"],
        )
    return out


def engine_fingerprint(engine: GnnPeEngine) -> str:
    """Content digest of everything match-relevant — two engines with
    equal fingerprints return identical matches (and match order).

    Telemetry (probe counters, offline timings) is excluded: a replica
    that served reads diverges there without any bearing on state.
    """
    meta, arrays = engine_state(engine)
    h = hashlib.blake2b(digest_size=16)
    for k in sorted(arrays):
        if k in ("plp", "ppr"):
            continue
        x = np.ascontiguousarray(arrays[k])
        h.update(k.encode())
        h.update(str(x.dtype).encode())
        h.update(np.asarray(x.shape, np.int64).tobytes())
        h.update(x.tobytes())
    stable = {
        "epoch": meta["epoch"],
        "fingerprint": meta["fingerprint"],
        "delta_epoch": meta["delta_epoch"],
        "n_compactions": meta["n_compactions"],
        "pending": meta["pending_compaction"],
        "models": [
            {k: mm[k] for k in ("n_tomb", "version", "group_size", "quantize")}
            for mm in meta["models"]
        ],
    }
    h.update(json.dumps(stable, sort_keys=True).encode())
    return h.hexdigest()


# ------------------------------------------------------------------ store --


class SnapshotStore:
    """Engine snapshots keyed by delta epoch, verified on both ends."""

    def __init__(self, directory, keep: int = 3):
        self.mgr = CheckpointManager(directory, keep=keep)

    def save(self, engine: GnnPeEngine, subscriptions: dict | None = None) -> int:
        t0 = time.perf_counter()
        meta, arrays = engine_state(engine, subscriptions)
        state = {_META_KEY: np.asarray(json.dumps(meta)), **arrays}
        step = int(engine.epoch)
        self.mgr.save(step, state)
        _M_SNAP_S.observe(time.perf_counter() - t0)
        _M_SNAP_BYTES.set(sum(a.nbytes for a in arrays.values()))
        _M_SNAPSHOTS.inc()
        return step

    def latest_epoch(self) -> int | None:
        return self.mgr.latest_step()

    def load(self, step: int | None = None):
        """→ ``(engine, meta, arrays, epoch)``; ``step=None`` falls back to
        the newest snapshot that passes manifest verification."""
        arrays, got = self.mgr.restore_arrays(step)
        engine, meta = restore_engine(arrays)
        if int(meta["epoch"]) != int(got):
            raise CorruptCheckpointError(
                f"snapshot step {got} carries epoch {meta['epoch']}"
            )
        return engine, meta, arrays, int(got)
