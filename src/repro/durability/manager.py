"""The durability manager: WAL + snapshot store behind one config.

Layout under ``DurabilityConfig.directory``::

    wal/        seg_<n>.wal           (durability/wal.py)
    snapshots/  step_<epoch>.npz + .manifest.json  (dist/checkpoint.py)

Protocol (wired into ``MatchServer.apply_update_tick``):

1. ``log_epoch(epoch, updates, …)`` — frame + fsync the batch *before*
   it is applied (log-before-apply: a crash in the gap replays the
   logged epoch, an applied-but-unlogged epoch cannot exist);
2. apply the batch to the engine;
3. ``after_apply(engine)`` — every ``snapshot_every`` epochs, write a
   verified snapshot (carrying the live subscription table), rotate the
   WAL, and prune segments the snapshot supersedes.

Standing-query registrations flow through ``log_subscribe`` /
``log_unsubscribe`` so recovery can rebuild the registry: subs newer
than the snapshot come from the WAL, older ones ride in the snapshot.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

from .snapshot import SnapshotStore
from .wal import WriteAheadLog

__all__ = ["DurabilityConfig", "Durability"]


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    directory: str
    snapshot_every: int = 8  # epochs between snapshots; 0 = WAL only
    segment_bytes: int = 4 << 20
    fsync: bool = True
    keep_snapshots: int = 3
    genesis_snapshot: bool = True  # snapshot the freshly built engine at open


class Durability:
    def __init__(self, cfg: DurabilityConfig, crash: object | None = None):
        self.cfg = cfg
        root = Path(cfg.directory)
        self.wal = WriteAheadLog(
            root / "wal", segment_bytes=cfg.segment_bytes, fsync=cfg.fsync
        )
        self.snapshots = SnapshotStore(root / "snapshots", keep=cfg.keep_snapshots)
        self.crash = crash  # faults.CrashPoint | None
        self.open_info = self.wal.open()
        self.subscriptions: dict = {}  # sub_id -> (query_graph, tenant)
        self._epochs_since_snapshot = 0
        # mid-snapshot kill point: between the npz commit and the manifest
        # commit — the window that leaves an uncommitted (= skipped) step
        self.snapshots.mgr._pre_commit = lambda: self._hit("mid_snapshot")

    def _hit(self, point: str) -> None:
        if self.crash is not None:
            self.crash.hit(point)

    # ---------------------------------------------------------- journal ---
    def log_epoch(self, epoch: int, updates: list, strategy: str, compaction: str) -> None:
        self._hit("before_log")
        arrays = {}
        for i, u in enumerate(updates):
            for k, v in u.to_arrays().items():
                arrays[f"u{i}_{k}"] = v
        self.wal.append(
            "epoch",
            meta={
                "epoch": int(epoch),
                "n_updates": len(updates),
                "strategy": strategy,
                "compaction": compaction,
            },
            arrays=arrays,
        )
        self._hit("after_log")

    def log_subscribe(self, sub_id: int, query, tenant: str = "") -> None:
        self.subscriptions[int(sub_id)] = (query, tenant)
        self.wal.append(
            "sub",
            meta={"sub_id": int(sub_id), "tenant": str(tenant)},
            arrays={"offsets": query.offsets, "nbrs": query.nbrs, "labels": query.labels},
        )

    def log_unsubscribe(self, sub_id: int) -> None:
        self.subscriptions.pop(int(sub_id), None)
        self.wal.append("unsub", meta={"sub_id": int(sub_id)})

    # --------------------------------------------------------- snapshot ---
    def after_apply(self, engine) -> bool:
        """Snapshot-cadence hook; returns True if a snapshot was taken."""
        self._hit("after_apply")
        self._epochs_since_snapshot += 1
        if not self.cfg.snapshot_every:
            return False
        if self._epochs_since_snapshot < self.cfg.snapshot_every:
            return False
        self.snapshot(engine)
        return True

    def snapshot(self, engine) -> int:
        step = self.snapshots.save(engine, self.subscriptions)
        self._hit("after_snapshot")
        # everything at or below `step` is now superseded; rotate first so
        # the active segment seals and whole-segment pruning can take it
        self.wal.rotate()
        self.wal.prune(step)
        self._epochs_since_snapshot = 0
        return step

    def close(self) -> None:
        self.wal.close()
