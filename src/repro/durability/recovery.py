"""Crash recovery: newest valid snapshot + WAL-suffix replay.

``recover_engine`` makes a restarted engine byte-identical to a replica
that never crashed: load the newest snapshot that passes manifest
verification (corrupt/uncommitted steps are skipped), then replay the
WAL's epoch records ``snapshot_epoch+1 … tip`` through the ordinary
``apply_updates`` — deterministic under frozen GNN params, so state
after replay equals state of an uninterrupted run at the same epoch
(``snapshot.engine_fingerprint`` is the proof obligation the tests and
bench discharge).  Log-before-apply means a crash between log and apply
simply replays the logged epoch; a torn WAL tail truncates back to the
last durable epoch.  Anything else — an epoch gap, mid-stream
corruption, a replay that lands on the wrong epoch — raises
:class:`RecoveryError` rather than serving wrong matches.

The standing-query table is rebuilt from the snapshot's subscription
payload plus surviving WAL ``sub``/``unsub`` records; ``recover_server``
re-registers each under its original id (one full refresh per
subscription, by construction of ``StandingQueryRegistry.register``).
"""
from __future__ import annotations

import time

from ..core.delta import GraphUpdate
from ..graphs.graph import Graph
from ..obs import REGISTRY
from .manager import Durability, DurabilityConfig
from .snapshot import restore_engine, restore_subscriptions
from .wal import WalRecord

import numpy as np

__all__ = ["RecoveryError", "recover_engine", "recover_server"]

_M_RECOVERIES = REGISTRY.counter(
    "gnnpe_recovery_total", "recovery attempts", labels=("outcome",)
)
_M_RECOVERY_S = REGISTRY.histogram("gnnpe_recovery_seconds", "snapshot load + WAL replay")
_M_REPLAYED = REGISTRY.gauge("gnnpe_recovery_replayed_epochs", "epochs replayed last recovery")


class RecoveryError(RuntimeError):
    """The directory does not reconstruct a provably consistent state."""


def _record_updates(rec: WalRecord) -> list[GraphUpdate]:
    out = []
    for i in range(int(rec.meta["n_updates"])):
        out.append(
            GraphUpdate.from_arrays(
                {k: rec.arrays[f"u{i}_{k}"] for k in
                 ("add_edges", "remove_edges", "add_vertex_labels", "remove_vertices")}
            )
        )
    return out


def recover_engine(durability) -> tuple:
    """→ ``(engine, info)`` from a :class:`Durability` (or its config).

    ``info``: snapshot_epoch, replayed, epoch, truncated_bytes,
    subscriptions ``{sid: (query, tenant)}``, recovery_s.
    """
    t0 = time.perf_counter()
    dur = durability if isinstance(durability, Durability) else Durability(durability)
    try:
        try:
            arrays, snap_epoch = dur.snapshots.mgr.restore_arrays()
        except FileNotFoundError as e:
            raise RecoveryError(f"no valid snapshot under {dur.snapshots.mgr.dir}") from e
        engine, meta = restore_engine(arrays)
        subs = restore_subscriptions(meta, arrays)

        replayed = 0
        expect = int(snap_epoch) + 1
        for rec in dur.wal.records():  # surviving records are a stream suffix
            if rec.type == "epoch":
                e = rec.epoch
                if e <= snap_epoch:
                    continue  # superseded by the snapshot (un-pruned segment)
                if e != expect:
                    raise RecoveryError(f"WAL epoch gap: expected {expect}, found {e}")
                engine.apply_updates(
                    _record_updates(rec),
                    strategy=rec.meta.get("strategy", "delta"),
                    compaction=rec.meta.get("compaction", "inline"),
                )
                if engine.epoch != e:
                    raise RecoveryError(
                        f"replay of epoch {e} landed on engine epoch {engine.epoch}"
                    )
                expect += 1
                replayed += 1
            elif rec.type == "sub":
                sid = int(rec.meta["sub_id"])
                subs[sid] = (
                    Graph(
                        offsets=np.asarray(rec.arrays["offsets"], np.int64),
                        nbrs=np.asarray(rec.arrays["nbrs"], np.int32),
                        labels=np.asarray(rec.arrays["labels"], np.int32),
                    ),
                    rec.meta.get("tenant", ""),
                )
            elif rec.type == "unsub":
                subs.pop(int(rec.meta["sub_id"]), None)
    except BaseException:
        _M_RECOVERIES.labels(outcome="error").inc()
        raise
    dur.subscriptions = dict(subs)
    dt = time.perf_counter() - t0
    _M_RECOVERIES.labels(outcome="ok").inc()
    _M_RECOVERY_S.observe(dt)
    _M_REPLAYED.set(replayed)
    info = {
        "snapshot_epoch": int(snap_epoch),
        "replayed": replayed,
        "epoch": int(engine.epoch),
        "truncated_bytes": int(dur.wal.truncated_bytes),
        "subscriptions": subs,
        "recovery_s": dt,
    }
    return engine, info


def recover_server(durability, serve_cfg=None) -> tuple:
    """Recover a :class:`MatchServer` → ``(server, info)``.

    Re-registers every journaled subscription under its original id;
    each re-registration is one full refresh whose delta (the complete
    current match set) lands in ``server.match_deltas`` for the
    reconnecting subscriber to drain.
    """
    import dataclasses

    from ..serve.match_server import MatchServeConfig, MatchServer

    dur = durability if isinstance(durability, Durability) else Durability(durability)
    engine, info = recover_engine(dur)
    serve_cfg = serve_cfg or MatchServeConfig()
    if serve_cfg.durability is not dur:
        serve_cfg = dataclasses.replace(serve_cfg, durability=dur)
    server = MatchServer(engine, serve_cfg)
    for sid in sorted(info["subscriptions"]):
        q, tenant = info["subscriptions"][sid]
        server.resubscribe(sid, q, tenant=tenant)
    return server, info


def recover_engine_from_dir(directory, **cfg_kwargs):
    """Convenience: recover from a durability directory path."""
    return recover_engine(DurabilityConfig(directory=str(directory), **cfg_kwargs))
