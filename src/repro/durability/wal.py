"""Checksummed, fsync'd write-ahead log for the update stream.

Record framing (shared with ``dist.cluster.DirExchange`` blobs)::

    | magic "GWR1" (4B) | payload_len u32 LE | crc32(payload) u32 LE | payload |

The payload is a flat binary record: a length-prefixed JSON header
(record type, epoch id, strategy, per-array dtype/shape manifest)
followed by raw C-contiguous array bytes (the serialized
``GraphUpdate`` batch, or a standing-query graph).  Appends are framed,
written, flushed, and ``fsync``'d before the caller may apply the
update (log-before-apply), so every *acknowledged* epoch is on disk.

Segments: ``seg_<n>.wal`` files, rotated once the active segment
exceeds ``segment_bytes`` (and on every snapshot, so pruning works at
whole-segment granularity).  On ``open()``:

* a frame that fails validation at the *tail* of the last segment —
  short header, short payload, or CRC mismatch with no valid frame
  after it — is a torn write: the tail is truncated and logging
  resumes (recovering to the last durable epoch, which is a state a
  never-crashed replica also passed through);
* a bad frame *followed by* a valid frame, or any bad frame in a
  non-final segment, cannot be a torn write — that is corruption, and
  ``open()`` fails loudly with :class:`CorruptWalError` rather than
  silently dropping acknowledged epochs.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import struct
import time
import zlib
from pathlib import Path

import numpy as np

from ..obs import REGISTRY

__all__ = [
    "CorruptRecordError",
    "CorruptWalError",
    "WalRecord",
    "WriteAheadLog",
    "frame_payload",
    "unframe_payload",
]

_MAGIC = b"GWR1"
_HEADER = struct.Struct("<4sII")  # magic, payload_len, crc32

_M_RECORDS = REGISTRY.counter(
    "gnnpe_wal_records_total", "WAL records appended", labels=("type",)
)
_M_BYTES = REGISTRY.counter("gnnpe_wal_bytes_total", "framed WAL bytes appended")
_M_APPEND_S = REGISTRY.histogram(
    "gnnpe_wal_append_seconds", "append + fsync latency per WAL record"
)
_M_TRUNCATED = REGISTRY.counter(
    "gnnpe_wal_truncated_bytes_total", "torn-tail bytes dropped at open()"
)
_M_SEGMENTS = REGISTRY.gauge("gnnpe_wal_segments", "live WAL segment files")


class CorruptRecordError(ValueError):
    """A single framed blob failed magic/length/CRC validation."""


class CorruptWalError(RuntimeError):
    """Mid-stream WAL corruption (not a torn tail) — refuse to recover."""


def frame_payload(payload: bytes) -> bytes:
    """Wrap ``payload`` in the length + CRC32 frame."""
    return _HEADER.pack(_MAGIC, len(payload), zlib.crc32(payload)) + payload


def unframe_payload(blob: bytes) -> bytes:
    """Validate and strip the frame of a single-record blob.

    Raises :class:`CorruptRecordError` on short/garbled/torn blobs —
    used by ``DirExchange`` to reject torn exchange files up front
    instead of failing midway through ``np.load``.
    """
    if len(blob) < _HEADER.size:
        raise CorruptRecordError(f"blob shorter than frame header ({len(blob)} B)")
    magic, ln, crc = _HEADER.unpack_from(blob)
    if magic != _MAGIC:
        raise CorruptRecordError(f"bad frame magic {magic!r}")
    payload = blob[_HEADER.size : _HEADER.size + ln]
    if len(payload) != ln:
        raise CorruptRecordError(f"short payload: {len(payload)} of {ln} B")
    if zlib.crc32(payload) != crc:
        raise CorruptRecordError("payload CRC mismatch")
    return payload


@dataclasses.dataclass
class WalRecord:
    type: str
    meta: dict
    arrays: dict

    @property
    def epoch(self) -> int | None:
        e = self.meta.get("epoch")
        return None if e is None else int(e)


def encode_record(rtype: str, meta: dict | None = None, arrays: dict | None = None) -> bytes:
    """Record payload: u32 header length + JSON header + raw array bytes.

    The header carries the record type, the meta dict, and per-array
    ``[name, dtype, shape]`` entries in write order; array bodies follow
    back to back as C-contiguous raw bytes.  Deliberately NOT npz —
    zipfile adds ~0.5 ms of per-member bookkeeping to a sub-2 KB record,
    which is the same order as the fsync the WAL exists to pay, and its
    CRC duplicates the frame checksum that already guards the payload.
    """
    entries = []
    bodies = []
    for k, v in (arrays or {}).items():
        a = np.ascontiguousarray(np.asarray(v))
        entries.append([k, a.dtype.str, list(a.shape)])
        bodies.append(a.tobytes())
    header = json.dumps(
        {"type": rtype, "meta": meta or {}, "arrays": entries}, separators=(",", ":")
    ).encode()
    return b"".join([struct.pack("<I", len(header)), header, *bodies])


def decode_record(payload: bytes) -> WalRecord:
    try:
        (hlen,) = struct.unpack_from("<I", payload)
        header = json.loads(payload[4 : 4 + hlen])
        arrays = {}
        off = 4 + hlen
        for k, dtype, shape in header["arrays"]:
            dt = np.dtype(dtype)
            n = dt.itemsize * int(np.prod(shape, dtype=np.int64))
            arrays[k] = np.frombuffer(payload[off : off + n], dtype=dt).reshape(shape)
            off += n
        if off != len(payload):
            raise ValueError(f"{len(payload) - off} trailing bytes")
    except CorruptRecordError:
        raise
    except Exception as e:  # structural damage that slipped past the CRC
        raise CorruptRecordError(f"undecodable WAL payload: {e}") from e
    return WalRecord(type=str(header.get("type", "?")), meta=dict(header["meta"]), arrays=arrays)


def _scan_frames(data: bytes) -> tuple[list[bytes], int, str | None]:
    """Parse consecutive frames → ``(payloads, valid_end, tail_error)``."""
    payloads: list[bytes] = []
    off = 0
    while True:
        if off == len(data):
            return payloads, off, None
        if len(data) - off < _HEADER.size:
            return payloads, off, "short header"
        magic, ln, crc = _HEADER.unpack_from(data, off)
        if magic != _MAGIC:
            return payloads, off, "bad magic"
        payload = data[off + _HEADER.size : off + _HEADER.size + ln]
        if len(payload) < ln:
            return payloads, off, "short payload"
        if zlib.crc32(payload) != crc:
            return payloads, off, "CRC mismatch"
        payloads.append(payload)
        off += _HEADER.size + ln


def _valid_frame_after(data: bytes, start: int) -> bool:
    """Any parseable frame beyond ``start``? → bad frame is not a torn tail."""
    i = data.find(_MAGIC, start + 1)
    while i != -1:
        if len(data) - i >= _HEADER.size:
            _, ln, crc = _HEADER.unpack_from(data, i)
            payload = data[i + _HEADER.size : i + _HEADER.size + ln]
            if len(payload) == ln and zlib.crc32(payload) == crc:
                return True
        i = data.find(_MAGIC, i + 1)
    return False


def _fsync_dir(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class WriteAheadLog:
    def __init__(self, directory, segment_bytes: int = 4 << 20, fsync: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.segment_bytes = int(segment_bytes)
        self.fsync = bool(fsync)
        self._fh: io.BufferedWriter | None = None
        self._seq: int = 0
        self.truncated_bytes = 0

    # --------------------------------------------------------- segments ---
    def _seg_path(self, seq: int) -> Path:
        return self.dir / f"seg_{seq:08d}.wal"

    def segments(self) -> list[tuple[int, Path]]:
        out = []
        for p in self.dir.glob("seg_*.wal"):
            try:
                out.append((int(p.stem[4:]), p))
            except ValueError:
                continue
        return sorted(out)

    # ------------------------------------------------------------- open ---
    def open(self) -> dict:
        """Scan + validate every segment, truncate a torn tail, arm appends.

        Returns ``{"records", "truncated_bytes", "segments"}``.  Raises
        :class:`CorruptWalError` on mid-stream corruption.
        """
        self.close()
        segs = self.segments()
        n_records = 0
        truncated = 0
        for k, (seq, path) in enumerate(segs):
            data = path.read_bytes()
            payloads, valid_end, tail_err = _scan_frames(data)
            n_records += len(payloads)
            if tail_err is None:
                continue
            is_last = k == len(segs) - 1
            if not is_last or _valid_frame_after(data, valid_end):
                raise CorruptWalError(
                    f"{path.name}: {tail_err} at offset {valid_end} is not a torn tail"
                )
            truncated = len(data) - valid_end
            with open(path, "r+b") as f:
                f.truncate(valid_end)
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())
        self.truncated_bytes = truncated
        if truncated:
            _M_TRUNCATED.inc(truncated)
        self._seq = segs[-1][0] if segs else 0
        self._fh = open(self._seg_path(self._seq), "ab")
        if self.fsync:
            _fsync_dir(self.dir)
        _M_SEGMENTS.set(max(len(segs), 1))
        return {"records": n_records, "truncated_bytes": truncated, "segments": len(segs)}

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # ------------------------------------------------------------ write ---
    def append(self, rtype: str, meta: dict | None = None, arrays: dict | None = None) -> None:
        if self._fh is None:
            raise RuntimeError("WriteAheadLog.append before open()")
        t0 = time.perf_counter()
        frame = frame_payload(encode_record(rtype, meta, arrays))
        if self._fh.tell() and self._fh.tell() + len(frame) > self.segment_bytes:
            self.rotate()
        self._fh.write(frame)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        _M_RECORDS.labels(type=rtype).inc()
        _M_BYTES.inc(len(frame))
        _M_APPEND_S.observe(time.perf_counter() - t0)

    def rotate(self) -> None:
        """Seal the active segment and start a fresh one."""
        if self._fh is None:
            raise RuntimeError("WriteAheadLog.rotate before open()")
        self._fh.close()
        self._seq += 1
        self._fh = open(self._seg_path(self._seq), "ab")
        if self.fsync:
            _fsync_dir(self.dir)
        _M_SEGMENTS.set(len(self.segments()))

    def prune(self, min_epoch: int) -> int:
        """Drop sealed segments fully covered by a snapshot at ``min_epoch``.

        Only whole segments go; the active segment always stays.  A
        sealed segment is prunable when none of its epoch records is
        newer than the snapshot (sub/unsub records are superseded too —
        the snapshot carries the live subscription table).
        """
        dropped = 0
        for seq, path in self.segments():
            if seq == self._seq:
                continue
            payloads, _, tail_err = _scan_frames(path.read_bytes())
            if tail_err is not None:
                continue  # leave anything suspicious for recovery to judge
            epochs = [r.epoch for r in map(decode_record, payloads) if r.epoch is not None]
            if epochs and max(epochs) > min_epoch:
                continue
            path.unlink()
            dropped += 1
        if dropped and self.fsync:
            _fsync_dir(self.dir)
        _M_SEGMENTS.set(len(self.segments()))
        return dropped

    # ------------------------------------------------------------- read ---
    def records(self) -> list[WalRecord]:
        """All records across segments, in append order (re-read from disk)."""
        out: list[WalRecord] = []
        for _, path in self.segments():
            payloads, valid_end, tail_err = _scan_frames(path.read_bytes())
            if tail_err is not None and _valid_frame_after(path.read_bytes(), valid_end):
                raise CorruptWalError(f"{path.name}: {tail_err} at offset {valid_end}")
            out.extend(decode_record(p) for p in payloads)
        return out

    def last_epoch(self) -> int | None:
        epochs = [r.epoch for r in self.records() if r.epoch is not None]
        return max(epochs) if epochs else None
