"""Crash-injection harness for the durability subsystem.

``CrashPoint`` raises :class:`SimulatedCrash` at a named kill point the
N-th time it is reached; the test harness then abandons the in-memory
engine (a real crash loses memory, only the directory survives) and
drives recovery on the same directory.  Kill points cover the whole
log → apply → snapshot window:

* ``before_log``    — update accepted, nothing durable yet
* ``after_log``     — WAL record durable, update **not** applied
* ``after_apply``   — applied, snapshot cadence not yet consulted
* ``mid_snapshot``  — npz durable, manifest (the commit point) missing
* ``after_snapshot``— snapshot committed, WAL not yet pruned

Corruption helpers (``flip_byte``/``truncate_tail``) model bit rot and
torn writes on WAL segments, snapshot npz files, and checkpoint leaves.
"""
from __future__ import annotations

import os
from pathlib import Path

__all__ = ["SimulatedCrash", "CrashPoint", "flip_byte", "truncate_tail"]

KILL_POINTS = (
    "before_log",
    "after_log",
    "after_apply",
    "mid_snapshot",
    "after_snapshot",
)


class SimulatedCrash(BaseException):
    """Raised at a kill point.  A ``BaseException`` so no tier's broad
    ``except Exception`` fault boundary can accidentally 'survive' it."""

    def __init__(self, point: str):
        super().__init__(f"simulated crash at {point}")
        self.point = point


class CrashPoint:
    """Crash the ``at``-th time ``hit(point)`` is reached (1-based)."""

    def __init__(self, point: str | None, at: int = 1):
        self.point = point
        self.at = int(at)
        self.count = 0

    def hit(self, point: str) -> None:
        if self.point != point:
            return
        self.count += 1
        if self.count >= self.at:
            raise SimulatedCrash(point)


def flip_byte(path, offset: int = -16) -> None:
    """XOR one byte in place (negative offsets index from the end)."""
    path = Path(path)
    data = bytearray(path.read_bytes())
    if not data:
        return
    data[offset % len(data)] ^= 0xFF
    path.write_bytes(bytes(data))


def truncate_tail(path, nbytes: int) -> None:
    """Chop ``nbytes`` off the end — a torn append."""
    path = Path(path)
    size = path.stat().st_size
    with open(path, "r+b") as f:
        f.truncate(max(0, size - int(nbytes)))
        f.flush()
        os.fsync(f.fileno())
