"""Crash-safe durability: WAL, verified snapshots, byte-identical recovery.

* :mod:`repro.durability.wal` — checksummed, fsync'd write-ahead log for
  the update stream (CRC32 + length framing, segment rotation,
  torn-tail truncation on open).
* :mod:`repro.durability.snapshot` — periodic full-engine snapshots
  through the digest-manifest-verified ``dist/checkpoint.py``.
* :mod:`repro.durability.recovery` — newest valid snapshot + WAL-suffix
  replay ⇒ a restarted server byte-identical to one that never crashed.
* :mod:`repro.durability.scrub` — invariant auditor (MBR/group bounds,
  tombstone/delta consistency vs a fresh enumerate), offline or as a
  server admin call.
* :mod:`repro.durability.faults` — crash-injection kill points and
  corruption helpers for the identity sweep.
"""
from .faults import CrashPoint, SimulatedCrash, flip_byte, truncate_tail
from .manager import Durability, DurabilityConfig
from .recovery import RecoveryError, recover_engine, recover_engine_from_dir, recover_server
from .scrub import scrub_engine
from .snapshot import (
    SnapshotIntegrityError,
    SnapshotStore,
    engine_fingerprint,
    engine_state,
    restore_engine,
)
from .wal import (
    CorruptRecordError,
    CorruptWalError,
    WalRecord,
    WriteAheadLog,
    frame_payload,
    unframe_payload,
)

__all__ = [
    "CrashPoint",
    "SimulatedCrash",
    "flip_byte",
    "truncate_tail",
    "Durability",
    "DurabilityConfig",
    "RecoveryError",
    "recover_engine",
    "recover_engine_from_dir",
    "recover_server",
    "scrub_engine",
    "SnapshotIntegrityError",
    "SnapshotStore",
    "engine_fingerprint",
    "engine_state",
    "restore_engine",
    "CorruptRecordError",
    "CorruptWalError",
    "WalRecord",
    "WriteAheadLog",
    "frame_payload",
    "unframe_payload",
]
