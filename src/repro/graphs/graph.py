"""Undirected labeled graph in CSR form (Definition 1 of the paper).

The whole framework treats graphs as flat numpy arrays so every stage
(star extraction, path enumeration, GNN batching, partition sharding)
is vectorizable and shardable.  Vertices are ``0..n-1``; labels are
small ints in ``[0, n_labels)``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Graph",
    "from_edge_list",
    "newman_watts_strogatz",
    "random_labels",
    "erdos_renyi",
    "induced_subgraph",
    "random_connected_query",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """CSR undirected labeled graph.

    offsets: (n+1,) int64 — CSR row pointers.
    nbrs:    (2|E|,) int32 — concatenated sorted neighbor lists.
    labels:  (n,) int32 — vertex labels ``L(v)``.
    """

    offsets: np.ndarray
    nbrs: np.ndarray
    labels: np.ndarray

    # ---- basic accessors -------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return int(self.labels.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.nbrs.shape[0] // 2)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets).astype(np.int32)

    @property
    def avg_degree(self) -> float:
        n = max(self.n_vertices, 1)
        return float(self.nbrs.shape[0]) / n

    def neighbors(self, v: int) -> np.ndarray:
        return self.nbrs[self.offsets[v] : self.offsets[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        i = np.searchsorted(row, v)
        return bool(i < row.shape[0] and row[i] == v)

    def edge_array(self) -> np.ndarray:
        """(|E|, 2) array of undirected edges with u < v."""
        src = np.repeat(np.arange(self.n_vertices, dtype=np.int32), self.degrees)
        mask = src < self.nbrs
        return np.stack([src[mask], self.nbrs[mask]], axis=1)

    def adjacency_sets(self) -> list[set[int]]:
        return [set(map(int, self.neighbors(v))) for v in range(self.n_vertices)]

    def validate(self) -> None:
        assert self.offsets[0] == 0 and self.offsets[-1] == self.nbrs.shape[0]
        for v in range(self.n_vertices):
            row = self.neighbors(v)
            assert np.all(np.diff(row) > 0), f"row {v} not strictly sorted"
            assert not np.any(row == v), f"self loop at {v}"


def from_edge_list(
    n_vertices: int,
    edges: Iterable[tuple[int, int]] | np.ndarray,
    labels: np.ndarray,
) -> Graph:
    """Build a CSR graph from an undirected edge list (dedup + both dirs)."""
    e = np.asarray(list(edges) if not isinstance(edges, np.ndarray) else edges)
    if e.size == 0:
        e = np.zeros((0, 2), dtype=np.int64)
    e = e.astype(np.int64)
    e = e[e[:, 0] != e[:, 1]]  # drop self loops
    both = np.concatenate([e, e[:, ::-1]], axis=0)
    # dedup directed pairs
    key = both[:, 0] * n_vertices + both[:, 1]
    _, idx = np.unique(key, return_index=True)
    both = both[np.sort(idx)]
    order = np.lexsort((both[:, 1], both[:, 0]))
    both = both[order]
    counts = np.bincount(both[:, 0], minlength=n_vertices)
    offsets = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return Graph(
        offsets=offsets,
        nbrs=both[:, 1].astype(np.int32),
        labels=np.asarray(labels, dtype=np.int32),
    )


# ---- generators (paper §6.1: NWS small-world + Uniform/Gaussian/Zipf labels)


def random_labels(
    n: int,
    n_labels: int,
    dist: str = "uniform",
    seed: int = 0,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        lab = rng.integers(0, n_labels, size=n)
    elif dist == "gaussian":
        raw = rng.normal(loc=n_labels / 2.0, scale=max(n_labels / 6.0, 1.0), size=n)
        lab = np.clip(np.round(raw), 0, n_labels - 1)
    elif dist == "zipf":
        # Zipf over the label domain with exponent 1.5, rejection-free.
        ranks = np.arange(1, n_labels + 1, dtype=np.float64)
        p = ranks ** -1.5
        p /= p.sum()
        lab = rng.choice(n_labels, size=n, p=p)
    else:
        raise ValueError(f"unknown label distribution: {dist}")
    return lab.astype(np.int32)


def newman_watts_strogatz(
    n: int,
    k: int = 4,
    p: float = 0.1,
    n_labels: int = 500,
    label_dist: str = "uniform",
    seed: int = 0,
) -> Graph:
    """Newman–Watts–Strogatz small-world graph (paper's synthetic generator).

    Ring lattice with k nearest neighbors plus shortcuts added w.p. ``p``
    per lattice edge (no rewiring — NWS keeps the ring, so connected).
    """
    rng = np.random.default_rng(seed)
    half = max(k // 2, 1)
    src = np.repeat(np.arange(n, dtype=np.int64), half)
    d = np.tile(np.arange(1, half + 1, dtype=np.int64), n)
    dst = (src + d) % n
    lattice = np.stack([src, dst], axis=1)
    n_short = rng.binomial(lattice.shape[0], p)
    su = rng.integers(0, n, size=n_short)
    sv = rng.integers(0, n, size=n_short)
    shortcuts = np.stack([su, sv], axis=1)
    edges = np.concatenate([lattice, shortcuts], axis=0)
    labels = random_labels(n, n_labels, label_dist, seed=seed + 1)
    return from_edge_list(n, edges, labels)


def erdos_renyi(
    n: int,
    avg_degree: float = 4.0,
    n_labels: int = 8,
    label_dist: str = "uniform",
    seed: int = 0,
) -> Graph:
    """G(n, m) random graph with the requested average degree."""
    rng = np.random.default_rng(seed)
    m = int(round(n * avg_degree / 2.0))
    u = rng.integers(0, n, size=2 * m + 8)
    v = rng.integers(0, n, size=2 * m + 8)
    keep = u != v
    edges = np.stack([u[keep], v[keep]], axis=1)[:m]
    labels = random_labels(n, n_labels, label_dist, seed=seed + 1)
    return from_edge_list(n, edges, labels)


def induced_subgraph(g: Graph, vertices: Sequence[int]) -> tuple[Graph, np.ndarray]:
    """Induced subgraph on ``vertices``; returns (subgraph, original ids)."""
    vs = np.asarray(sorted(set(int(v) for v in vertices)), dtype=np.int64)
    remap = -np.ones(g.n_vertices, dtype=np.int64)
    remap[vs] = np.arange(vs.shape[0])
    edges = []
    for new_u, u in enumerate(vs):
        for w in g.neighbors(int(u)):
            if remap[w] >= 0 and remap[w] > new_u:
                edges.append((new_u, int(remap[w])))
    return from_edge_list(vs.shape[0], edges, g.labels[vs]), vs


def random_connected_query(
    g: Graph,
    n_vertices: int,
    seed: int = 0,
    avg_degree: float | None = None,
) -> Graph:
    """Sample a connected query graph from G by random expansion (paper §6.1:
    queries are sampled connected subgraphs of the data graph)."""
    rng = np.random.default_rng(seed)
    for _attempt in range(64):
        start = int(rng.integers(0, g.n_vertices))
        chosen: list[int] = [start]
        frontier = set(map(int, g.neighbors(start)))
        while len(chosen) < n_vertices and frontier:
            nxt = int(rng.choice(sorted(frontier)))
            chosen.append(nxt)
            frontier |= set(map(int, g.neighbors(nxt)))
            frontier -= set(chosen)
        if len(chosen) == n_vertices:
            sub, _ids = induced_subgraph(g, chosen)
            if avg_degree is not None and sub.avg_degree > avg_degree:
                # drop random edges (keeping connectivity) to hit target degree
                sub = _sparsify(sub, avg_degree, rng)
            if sub.nbrs.shape[0] > 0:
                return sub
    raise RuntimeError("could not sample a connected query graph")


def _sparsify(g: Graph, avg_degree: float, rng: np.random.Generator) -> Graph:
    edges = g.edge_array()
    target_m = max(g.n_vertices - 1, int(round(avg_degree * g.n_vertices / 2.0)))
    if edges.shape[0] <= target_m:
        return g
    # keep a random spanning tree, then random extras
    perm = rng.permutation(edges.shape[0])
    parent = np.arange(g.n_vertices)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    keep = []
    extra = []
    for i in perm:
        u, v = int(edges[i, 0]), int(edges[i, 1])
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
            keep.append(i)
        else:
            extra.append(i)
    need = target_m - len(keep)
    keep += extra[: max(need, 0)]
    return from_edge_list(g.n_vertices, edges[np.asarray(keep, dtype=np.int64)], g.labels)
