from .graph import (
    Graph,
    erdos_renyi,
    from_edge_list,
    induced_subgraph,
    newman_watts_strogatz,
    random_connected_query,
    random_labels,
)
from .partition import Partitioning, expanded_partition, partition_graph
from .sampler import SampledBatch, SampledBlock, sample_fanout

__all__ = [
    "Graph",
    "from_edge_list",
    "newman_watts_strogatz",
    "erdos_renyi",
    "random_labels",
    "induced_subgraph",
    "random_connected_query",
    "Partitioning",
    "partition_graph",
    "expanded_partition",
    "SampledBatch",
    "SampledBlock",
    "sample_fanout",
]
