"""Fanout neighbor sampler (GraphSAGE-style) — required by ``minibatch_lg``.

Produces fixed-shape (padded) sampled blocks so the result is directly
jittable/shardable: every layer yields an ELL block
``(n_dst, fanout)`` of neighbor indices into the previous layer's
vertex set, with -1 padding and a validity mask.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import Graph

__all__ = ["SampledBlock", "SampledBatch", "sample_fanout"]


@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One message-passing layer's sampled bipartite block."""

    nbr_index: np.ndarray  # (n_dst, fanout) int32 indices into src vertex list
    mask: np.ndarray  # (n_dst, fanout) bool — True where a real neighbor

    @property
    def n_dst(self) -> int:
        return int(self.nbr_index.shape[0])

    @property
    def fanout(self) -> int:
        return int(self.nbr_index.shape[1])


@dataclasses.dataclass(frozen=True)
class SampledBatch:
    """Layered fanout sample rooted at ``seeds``.

    vertex_ids[k] is the global id list for layer k (k=0 is the innermost
    = seeds); blocks[k] gathers from vertex_ids[k+1] into vertex_ids[k].
    """

    seeds: np.ndarray
    vertex_ids: list[np.ndarray]
    blocks: list[SampledBlock]


def sample_fanout(
    g: Graph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    seed: int = 0,
) -> SampledBatch:
    rng = np.random.default_rng(seed)
    vertex_ids = [np.asarray(seeds, dtype=np.int32)]
    blocks: list[SampledBlock] = []
    cur = vertex_ids[0]
    for fanout in fanouts:
        n_dst = cur.shape[0]
        nbr_global = -np.ones((n_dst, fanout), dtype=np.int64)
        for i, v in enumerate(cur):
            row = g.neighbors(int(v))
            if row.shape[0] == 0:
                continue
            if row.shape[0] <= fanout:
                take = row
            else:
                take = rng.choice(row, size=fanout, replace=False)
            nbr_global[i, : take.shape[0]] = take
        mask = nbr_global >= 0
        # next-layer vertex set = union of dst vertices and sampled neighbors
        uniq = np.unique(np.concatenate([cur.astype(np.int64), nbr_global[mask]]))
        remap = {int(v): i for i, v in enumerate(uniq)}
        nbr_index = np.zeros((n_dst, fanout), dtype=np.int32)
        for i in range(n_dst):
            for f in range(fanout):
                if mask[i, f]:
                    nbr_index[i, f] = remap[int(nbr_global[i, f])]
        blocks.append(SampledBlock(nbr_index=nbr_index, mask=mask))
        vertex_ids.append(uniq.astype(np.int32))
        cur = uniq.astype(np.int32)
    return SampledBatch(seeds=np.asarray(seeds, dtype=np.int32), vertex_ids=vertex_ids, blocks=blocks)
