"""METIS-like balanced min-edge-cut partitioner (paper Alg. 1 line 1).

Real METIS is multilevel KL; here we implement a deterministic two-stage
scheme that is (a) dependency-free, (b) fast at millions of edges, and
(c) produces balanced partitions with low edge cut on the small-world /
power-law graphs the paper uses:

  1. seeded BFS region growing: m BFS frontiers grown round-robin from
     degree-spread seeds until every vertex is claimed (balance enforced
     by per-partition capacity);
  2. boundary refinement: a few Kernighan–Lin-style sweeps moving boundary
     vertices to the neighboring partition with max gain while respecting
     capacity.

Partitions drive both the paper pipeline (one GNN model / index per
partition, trained in parallel across the mesh) and the sharded matcher.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import Graph

__all__ = ["Partitioning", "partition_graph", "expanded_partition"]


@dataclasses.dataclass(frozen=True)
class Partitioning:
    assignment: np.ndarray  # (n,) int32 partition id per vertex
    n_parts: int

    def members(self, j: int) -> np.ndarray:
        return np.nonzero(self.assignment == j)[0].astype(np.int32)

    def edge_cut(self, g: Graph) -> int:
        e = g.edge_array()
        return int(np.sum(self.assignment[e[:, 0]] != self.assignment[e[:, 1]]))

    def sizes(self) -> np.ndarray:
        return np.bincount(self.assignment, minlength=self.n_parts)


def partition_graph(g: Graph, n_parts: int, seed: int = 0, refine_sweeps: int = 2) -> Partitioning:
    n = g.n_vertices
    if n_parts <= 1 or n <= n_parts:
        return Partitioning(np.zeros(n, dtype=np.int32), max(n_parts, 1))
    rng = np.random.default_rng(seed)
    cap = int(np.ceil(n / n_parts * 1.05))

    # --- stage 1: BFS region growing from spread seeds -------------------
    order = np.argsort(-g.degrees, kind="stable")
    seeds = order[:: max(n // n_parts, 1)][:n_parts]
    if seeds.shape[0] < n_parts:
        extra = rng.choice(n, size=n_parts - seeds.shape[0], replace=False)
        seeds = np.concatenate([seeds, extra])
    assignment = -np.ones(n, dtype=np.int32)
    frontiers: list[list[int]] = []
    sizes = np.zeros(n_parts, dtype=np.int64)
    for j, s in enumerate(seeds):
        s = int(s)
        if assignment[s] == -1:
            assignment[s] = j
            sizes[j] += 1
        frontiers.append([s])
    active = True
    while active:
        active = False
        for j in range(n_parts):
            if sizes[j] >= cap or not frontiers[j]:
                continue
            new_frontier: list[int] = []
            for u in frontiers[j]:
                for w in g.neighbors(u):
                    w = int(w)
                    if assignment[w] == -1 and sizes[j] < cap:
                        assignment[w] = j
                        sizes[j] += 1
                        new_frontier.append(w)
            frontiers[j] = new_frontier
            active = active or bool(new_frontier)
    # orphans (disconnected bits): round-robin to smallest partitions
    orphans = np.nonzero(assignment == -1)[0]
    for u in orphans:
        j = int(np.argmin(sizes))
        assignment[u] = j
        sizes[j] += 1

    # --- stage 2: boundary refinement (KL-style greedy sweeps) -----------
    for _ in range(refine_sweeps):
        moved = 0
        e = g.edge_array()
        boundary = np.unique(
            np.concatenate(
                [
                    e[assignment[e[:, 0]] != assignment[e[:, 1]], 0],
                    e[assignment[e[:, 0]] != assignment[e[:, 1]], 1],
                ]
            )
        )
        for u in boundary:
            u = int(u)
            cur = assignment[u]
            nbr_parts = assignment[g.neighbors(u)]
            if nbr_parts.size == 0:
                continue
            counts = np.bincount(nbr_parts, minlength=n_parts)
            best = int(np.argmax(counts))
            gain = counts[best] - counts[cur]
            if best != cur and gain > 0 and sizes[best] < cap and sizes[cur] > 1:
                assignment[u] = best
                sizes[cur] -= 1
                sizes[best] += 1
                moved += 1
        if moved == 0:
            break
    return Partitioning(assignment, n_parts)


def expanded_partition(g: Graph, part: Partitioning, j: int, hops: int) -> np.ndarray:
    """Vertex set of partition j expanded outward by ``hops`` (paper §4.2:
    paths of length l are rooted in G_j but may walk l hops outside)."""
    cur = set(map(int, part.members(j)))
    frontier = set(cur)
    for _ in range(hops):
        nxt: set[int] = set()
        for u in frontier:
            nxt.update(map(int, g.neighbors(u)))
        nxt -= cur
        cur |= nxt
        frontier = nxt
        if not frontier:
            break
    return np.asarray(sorted(cur), dtype=np.int32)
