"""Fault-tolerant training loop.

Production posture (DESIGN §5):
  * checkpoint/restart — CheckpointManager (atomic, async, elastic)
  * preemption — SIGTERM/SIGINT handler checkpoints then exits cleanly
  * straggler mitigation — per-step deadline watchdog; steps exceeding
    ``deadline_factor ×`` the trailing-median step time are logged and
    counted (on a real pod this feeds the coordinator's replace/skip
    decision; the hook is exercised in tests via an injected delay)
  * deterministic resume — data is (seed, step)-addressed, so restoring
    params/opt/step reproduces the exact batch sequence
  * optional gradient compression with error feedback (train/compress)
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable

import jax
import jax.numpy as jnp

from ..dist.checkpoint import CheckpointManager
from .compress import CompressionConfig, compress_grads, init_residual
from .optimizer import OptConfig, adamw_init, adamw_update

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    ckpt_keep: int = 3
    log_every: int = 10
    deadline_factor: float = 3.0  # straggler threshold vs trailing median
    async_checkpoint: bool = True
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    compression: CompressionConfig = dataclasses.field(default_factory=CompressionConfig)


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,  # (params, batch) -> (loss, metrics)
        params,
        batch_fn: Callable,  # step -> batch (deterministic)
        cfg: TrainerConfig,
        jit: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.opt_state = adamw_init(params)
        self.batch_fn = batch_fn
        self.step = 0
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
        self.residual = init_residual(params) if cfg.compression.kind != "none" else None
        self.straggler_events: list = []
        self.history: list = []
        self._preempted = False

        comp = cfg.compression

        def train_step(params, opt_state, residual, batch):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            if residual is not None:
                grads, residual = compress_grads(grads, residual, comp)
            new_params, new_opt, om = adamw_update(grads, opt_state, params, cfg.opt)
            return new_params, new_opt, residual, {"loss": loss, **metrics, **om}

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2)) if jit else train_step

    # ---------------------------------------------------------------- api --
    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def try_resume(self) -> bool:
        if self.ckpt.latest_step() is None:
            return False
        state = {"params": self.params, "opt": self.opt_state, "step": jnp.zeros((), jnp.int32)}
        restored, step = self.ckpt.restore(state)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = int(restored["step"])
        return True

    def _checkpoint(self):
        state = {
            "params": self.params,
            "opt": self.opt_state,
            "step": jnp.asarray(self.step, jnp.int32),
        }
        if self.cfg.async_checkpoint:
            self.ckpt.save_async(self.step, state)
        else:
            self.ckpt.save(self.step, state)

    def run(self, steps: int | None = None) -> dict:
        steps = steps if steps is not None else self.cfg.total_steps
        durations: list = []
        t_start = time.perf_counter()
        end = self.step + steps
        while self.step < end and not self._preempted:
            batch = self.batch_fn(self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, self.residual, metrics = self._step_fn(
                self.params, self.opt_state, self.residual, batch
            )
            loss = float(metrics["loss"])  # sync point (realistic pacing)
            dt = time.perf_counter() - t0
            # straggler watchdog
            if len(durations) >= 5:
                med = statistics.median(durations[-20:])
                if dt > self.cfg.deadline_factor * med:
                    self.straggler_events.append({"step": self.step, "dt": dt, "median": med})
            durations.append(dt)
            self.history.append({"step": self.step, "loss": loss, "dt": dt})
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
        if self._preempted:
            self._checkpoint()
        self.ckpt.wait()
        return {
            "final_step": self.step,
            "final_loss": self.history[-1]["loss"] if self.history else float("nan"),
            "wall_s": time.perf_counter() - t_start,
            "stragglers": len(self.straggler_events),
            "preempted": self._preempted,
        }
