"""Gradient compression with error feedback (distributed-optimization trick).

Two codecs applied to the gradient pytree *before* the (implicit) DP
all-reduce, both with error-feedback residual state so compression error
doesn't bias the optimizer (Karimireddy et al., arXiv:1901.09847):

* ``int8``  — per-tensor absmax-scaled int8 quantization (4× traffic cut)
* ``topk``  — magnitude top-k sparsification (k fraction kept)

``compress_grads`` returns the *decompressed* grads (what the update
sees) plus the new residual — numerically exactly what a real
compressed-collective implementation produces, so tests on CPU validate
convergence behaviour end to end.  ``wire_bytes`` reports the traffic
a real deployment would ship.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CompressionConfig", "init_residual", "compress_grads", "wire_bytes"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"  # none | int8 | topk
    topk_frac: float = 0.01


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_roundtrip(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(x, frac):
    flat = x.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(x.shape)


def compress_grads(grads, residual, cfg: CompressionConfig):
    """→ (decompressed_grads, new_residual)."""
    if cfg.kind == "none":
        return grads, residual

    def one(g, r):
        x = g.astype(jnp.float32) + r
        if cfg.kind == "int8":
            out = _int8_roundtrip(x)
        elif cfg.kind == "topk":
            out = _topk_roundtrip(x, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        return out, x - out  # error feedback

    pairs = jax.tree.map(one, grads, residual)
    outs = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return outs, res


def wire_bytes(params, cfg: CompressionConfig) -> int:
    """Bytes a DP all-reduce would ship per step under this codec."""
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    if cfg.kind == "int8":
        return n  # 1 byte/elem (+ negligible scales)
    if cfg.kind == "topk":
        return int(n * cfg.topk_frac) * 8  # value + index
    return n * 4
