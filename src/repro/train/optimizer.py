"""AdamW + schedules, pure JAX (no optax in this environment)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, opt_state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"lr": lr, "grad_norm": gn}
