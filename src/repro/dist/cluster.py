"""Multi-host cluster tier: scatter-gather matching over partition
owners (distributed GNN-PE, arXiv 2511.09052).

The single-process engine already shards the stacked probe over a
("part",) device mesh — but one process, one host, one result cache.
This module adds the missing tier:

    coordinator                      host 0 .. host H-1
    -----------                      ------------------
    plans (deg cache / dr round) --> probe owned partitions only
    scatter (qi, path) requests  --> (parts-scoped _probe_batch:
    gather candidate verts       <--  subset stack + delta + tombstones)
    assemble (ascending mi,
      main then delta)           --> join + refine at the coordinator

  * **Placement** — ``rebalance()`` feeds the engine's
    ``partition_stats()`` (the stacked probe's per-partition leaf-pair
    counters, candidate-row counts, rows, bytes) through the
    cost-ranked LPT placement of dist/placement.py; each host owns the
    partitions assigned to it.
  * **Identity** — hosts return exactly the candidate vertex arrays
    ``_match_many_core`` would gather locally (live main rows in index
    order, then delta rows), the coordinator assembles them in the same
    ascending-partition order and runs the same planner (shared plan
    cache) and join — so cluster ``match_many`` is byte-identical to
    single-process ``match_many`` at every delta epoch.
  * **Sharded cache** — ``ShardedResultCache`` homes each entry on the
    owner of its smallest contributing partition, so an update's
    invalidation stays local to the host that owns the mutated
    partition (serve/cache.py documents the split accounting).
  * **Host loss** — a host that dies mid-gather (``HostLostError``,
    which a wire timeout maps to) is re-probed by the coordinator over
    the lost host's partitions locally; matches are unaffected.
  * **Blue-green** — ``rebuild_generation`` snapshots, builds the next
    index generation off the serving path, persists it as a versioned
    artifact through dist/checkpoint.py's atomic ``CheckpointManager``,
    and installs under an epoch version check.

Process modes.  ``LocalHost`` simulates hosts in-process (the "local
cluster" fallback — same parts-scoped work a real host would do, minus
the wire).  ``ExchangeHost`` + ``serve_exchange_host`` speak an
atomic-rename npz protocol over a shared directory (``DirExchange``)
between real processes; ``init_distributed`` wires ``jax.distributed``
bootstrap when a multi-process launch provides a coordinator, falling
back to single-process local mode when it cannot.
"""

from __future__ import annotations

import io
import json
import os
import time
from pathlib import Path

import numpy as np

from ..core.index import hash_labels
from ..core.matcher import match_from_candidates, match_from_candidates_many
from ..core.planner import candidate_plan_paths, canonical_form
from ..graphs import Graph
from ..obs.export import EVENTS
from ..obs.metrics import REGISTRY as _OBS
from ..serve.cache import ShardedResultCache, canonical_matches, remap_matches
from .placement import DEFAULT_WEIGHTS, partition_costs, place_partitions

__all__ = [
    "HostLostError",
    "LocalHost",
    "ExchangeHost",
    "DirExchange",
    "serve_exchange_host",
    "ClusterEngine",
    "init_distributed",
]


class HostLostError(RuntimeError):
    """A host failed (or timed out) mid-gather; the coordinator
    re-probes its partitions locally."""


_M_CLUSTER = _OBS.counter(
    "gnnpe_cluster_events_total",
    "Cluster control/data-plane events since process start",
    labels=("event",),
)


def init_distributed(
    num_processes: int = 1,
    process_id: int = 0,
    coordinator_address: str | None = None,
    timeout_s: float = 60.0,
) -> dict:
    """``jax.distributed`` bootstrap with a single-process fallback.

    With ``num_processes > 1`` and a coordinator address, tries
    ``jax.distributed.initialize`` (gRPC coordination service) so every
    process shares one cluster view; any failure — no coordinator, an
    unsupported backend, a second initialize — degrades to local mode
    instead of raising, because the scatter-gather data plane does not
    depend on it (DirExchange carries the candidates either way).
    """
    if num_processes <= 1:
        return {"mode": "local", "num_processes": 1, "process_id": 0}
    try:
        import jax

        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes,
            process_id=process_id,
            initialization_timeout=int(timeout_s),
        )
        return {
            "mode": "distributed",
            "num_processes": num_processes,
            "process_id": process_id,
        }
    except Exception as exc:  # pragma: no cover - backend/version specific
        return {
            "mode": "local",
            "num_processes": num_processes,
            "process_id": process_id,
            "error": repr(exc),
        }


# ---------------------------------------------------------------------------
# hosts
# ---------------------------------------------------------------------------
class LocalHost:
    """One simulated host of the local cluster: probes its owned
    partitions through the engine's parts-scoped path (subset stack,
    delta buffers, tombstones) — the same work scoping a separate
    process would do, minus the wire.  ``fail_next`` injects a loss for
    the re-scatter tests."""

    def __init__(self, host_id: int, engine):
        self.host_id = int(host_id)
        self.engine = engine
        self.owned: list = []
        self.fail_next = False

    def probe(self, queries, requests, return_stats: bool = False):
        if self.fail_next:
            self.fail_next = False
            raise HostLostError(f"host {self.host_id} lost mid-gather")
        return self.engine.probe_candidates(
            queries, requests, parts=self.owned, return_stats=return_stats
        )


class DirExchange:
    """Shared-directory blob exchange — the 2-process smoke's data
    plane.  Writes stage to a tmp file, fsync, ``os.replace`` into
    place, then fsync the *directory* (the durable-rename contract: the
    replace itself is atomic against concurrent readers, but only the
    dir fsync pins the name→inode update across a power cut — without
    it a crashed writer can reboot into a directory where the blob it
    acknowledged never existed).  Blobs are CRC-framed npz payloads
    (durability/wal.py framing): a reader that races bit rot or a
    truncated copy gets a typed rejection up front instead of an
    arbitrary failure mid-``np.load``."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def put(self, key: str, meta: dict | None = None, arrays: dict | None = None) -> None:
        from ..durability.wal import frame_payload

        payload = {f"a_{k}": np.asarray(v) for k, v in (arrays or {}).items()}
        payload["__meta__"] = np.asarray(json.dumps(meta or {}))
        buf = io.BytesIO()
        np.savez(buf, **payload)
        final = self.root / f"{key}.npz"
        tmp = final.with_suffix(final.suffix + f".tmp{os.getpid()}")
        with open(tmp, "wb") as f:
            f.write(frame_payload(buf.getvalue()))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        dfd = os.open(self.root, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    def get(self, key: str, timeout: float = 60.0, poll: float = 0.01):
        from ..durability.wal import CorruptRecordError, unframe_payload

        final = self.root / f"{key}.npz"
        deadline = time.monotonic() + timeout
        while not final.exists():
            if time.monotonic() > deadline:
                raise HostLostError(f"timed out waiting for {key}")
            time.sleep(poll)
        try:
            blob = unframe_payload(final.read_bytes())
        except CorruptRecordError as e:
            # a torn/corrupt blob means the peer (or its disk) is gone —
            # surface it as the host-loss the coordinator already heals
            raise HostLostError(f"corrupt exchange blob {key}: {e}") from e
        with np.load(io.BytesIO(blob), allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            arrays = {k[2:]: z[k] for k in z.files if k.startswith("a_")}
        return meta, arrays


def _pack_queries(queries: list) -> tuple[dict, dict]:
    meta = {"nq": len(queries)}
    arrays = {}
    for i, q in enumerate(queries):
        arrays[f"q{i}_offsets"] = q.offsets
        arrays[f"q{i}_nbrs"] = q.nbrs
        arrays[f"q{i}_labels"] = q.labels
    return meta, arrays


def _unpack_queries(meta: dict, arrays: dict) -> list:
    return [
        Graph(
            np.asarray(arrays[f"q{i}_offsets"], np.int64),
            np.asarray(arrays[f"q{i}_nbrs"], np.int32),
            np.asarray(arrays[f"q{i}_labels"], np.int32),
        )
        for i in range(int(meta["nq"]))
    ]


def _pack_candidates(cands: dict) -> tuple[dict, dict]:
    keys = []
    arrays = {}
    for i, ((mi, qi, p), (main, dverts)) in enumerate(cands.items()):
        keys.append([int(mi), int(qi), [int(v) for v in p]])
        arrays[f"k{i}_m"] = main
        arrays[f"k{i}_d"] = dverts
    return {"keys": keys}, arrays


def _unpack_candidates(meta: dict, arrays: dict) -> dict:
    out = {}
    for i, (mi, qi, p) in enumerate(meta["keys"]):
        out[(int(mi), int(qi), tuple(int(v) for v in p))] = (
            np.asarray(arrays[f"k{i}_m"], np.int32),
            np.asarray(arrays[f"k{i}_d"], np.int32),
        )
    return out


class ExchangeHost:
    """Proxy for a host in another process: scatter writes
    ``req_<host>_<n>`` blobs, the remote ``serve_exchange_host`` loop
    answers ``resp_<host>_<n>``.  The parts to probe ride inside each
    request, so worker and coordinator need no placement
    synchronization; a timeout maps to ``HostLostError`` and the
    coordinator re-probes locally."""

    def __init__(self, host_id: int, exchange: DirExchange, timeout: float = 120.0):
        self.host_id = int(host_id)
        self.exchange = exchange
        self.timeout = float(timeout)
        self.owned: list = []
        self._seq = 0

    def probe(self, queries, requests, return_stats: bool = False):
        meta, arrays = _pack_queries(queries)
        meta["requests"] = [[int(qi), [int(v) for v in p]] for qi, p in requests]
        meta["parts"] = [int(mi) for mi in self.owned]
        meta["return_stats"] = bool(return_stats)
        rid = self._seq
        self._seq += 1
        self.exchange.put(f"req_{self.host_id}_{rid}", meta, arrays)
        rmeta, rarrays = self.exchange.get(
            f"resp_{self.host_id}_{rid}", timeout=self.timeout
        )
        cands = _unpack_candidates(rmeta, rarrays)
        if return_stats:
            stats = {
                (int(mi), int(qi), tuple(int(v) for v in p)): st
                for mi, qi, p, st in rmeta.get("stats", [])
            }
            return cands, stats
        return cands

    def stop(self) -> None:
        self.exchange.put(f"req_{self.host_id}_{self._seq}", {"stop": True}, {})
        self._seq += 1


def serve_exchange_host(
    engine, host_id: int, exchange: DirExchange, max_requests: int | None = None,
    timeout: float = 120.0,
) -> int:
    """Worker-process loop: answer the coordinator's probe requests for
    ``host_id`` until a stop blob (or silence past ``timeout``) arrives.
    Returns the number of requests served.  The worker holds a
    deterministic replica of the engine (same seed ⇒ identical build),
    so its parts-scoped candidates equal the coordinator's own."""
    n = 0
    while max_requests is None or n < max_requests:
        try:
            meta, arrays = exchange.get(f"req_{host_id}_{n}", timeout=timeout)
        except HostLostError:
            return n
        if meta.get("stop"):
            return n
        queries = _unpack_queries(meta, arrays)
        requests = [(int(qi), tuple(int(v) for v in p)) for qi, p in meta["requests"]]
        out = engine.probe_candidates(
            queries, requests, parts=meta["parts"],
            return_stats=bool(meta.get("return_stats", False)),
        )
        cands, st = out if meta.get("return_stats") else (out, None)
        rmeta, rarrays = _pack_candidates(cands)
        if st is not None:
            rmeta["stats"] = [
                [int(mi), int(qi), [int(v) for v in p], d]
                for (mi, qi, p), d in st.items()
            ]
        exchange.put(f"resp_{host_id}_{n}", rmeta, rarrays)
        n += 1
    return n


# ---------------------------------------------------------------------------
# the cluster engine
# ---------------------------------------------------------------------------
class ClusterEngine:
    """Scatter-gather ``match_many`` over partition-owner hosts.

    ``ClusterEngine(engine, n_hosts=4)`` simulates a 4-host local
    cluster; pass ``hosts=[...]`` (e.g. ``ExchangeHost`` proxies) to
    span processes.  The coordinator keeps the full engine — it plans,
    embeds queries, assembles gathered candidates and joins; hosts do
    the parts-scoped probe work.  ``cache_capacity > 0`` adds the
    partition-owner-sharded result cache.
    """

    def __init__(
        self,
        engine,
        n_hosts: int | None = None,
        hosts: list | None = None,
        cache_capacity: int = 0,
        weights: tuple = DEFAULT_WEIGHTS,
        durability=None,
    ):
        if hosts is None:
            hosts = [LocalHost(h, engine) for h in range(max(int(n_hosts or 1), 1))]
        if not hosts:
            raise ValueError("a cluster needs at least one host")
        self.engine = engine
        self.hosts = list(hosts)
        self.weights = weights
        self.placement = None
        # coordinator-side durability: the coordinator owns the engine, so
        # it journals the update stream exactly like a MatchServer would
        self.durability = None
        if durability is not None:
            from ..durability.manager import Durability

            self.durability = (
                durability if isinstance(durability, Durability) else Durability(durability)
            )
            if (
                self.durability.cfg.genesis_snapshot
                and self.durability.snapshots.latest_epoch() is None
            ):
                self.durability.snapshot(engine)
        self.cache = (
            ShardedResultCache(len(self.hosts), cache_capacity) if cache_capacity else None
        )
        self.stats = {"host_losses": 0, "scatter_rounds": 0, "requests_scattered": 0}
        self.rebalance()

    @property
    def n_hosts(self) -> int:
        return len(self.hosts)

    def rebalance(self):
        """(Re)compute the cost-ranked partition→host placement from the
        engine's current ``partition_stats()`` and install it on the
        hosts and the cache's owner map."""
        costs = partition_costs(self.engine.partition_stats(), self.weights)
        self.placement = place_partitions(costs, len(self.hosts))
        for h, host in enumerate(self.hosts):
            host.owned = self.placement.owned(h)
        if self.cache is not None:
            self.cache.set_placement(self.placement.host_of)
        _M_CLUSTER.labels(event="rebalance").inc()
        if EVENTS.active:
            EVENTS.emit(
                "rebalance",
                n_hosts=len(self.hosts),
                owned=[list(self.placement.owned(h)) for h in range(len(self.hosts))],
            )
        return self.placement

    # ------------------------------------------------------------- probes --
    def _scatter(self, queries: list, requests: list, return_stats: bool = False):
        """One probe round: fan ``requests`` to every owning host,
        gather the merged candidate dict.  A lost host's partitions are
        re-probed locally by the coordinator — matches unaffected."""
        gathered: dict = {}
        stats: dict | None = {} if return_stats else None
        self.stats["scatter_rounds"] += 1
        self.stats["requests_scattered"] += len(requests)
        _M_CLUSTER.labels(event="scatter_round").inc()
        _M_CLUSTER.labels(event="request_scattered").inc(len(requests))
        for host in self.hosts:
            if not host.owned:
                continue
            try:
                out = host.probe(queries, requests, return_stats=return_stats)
            except HostLostError:
                self.stats["host_losses"] += 1
                _M_CLUSTER.labels(event="host_loss").inc()
                if EVENTS.active:
                    EVENTS.emit(
                        "host_loss",
                        host=getattr(host, "host_id", None),
                        n_owned=len(host.owned),
                        reprobed_locally=True,
                    )
                out = self.engine.probe_candidates(
                    queries, requests, parts=host.owned, return_stats=return_stats
                )
            if return_stats:
                cands, st = out
                stats.update(st)
            else:
                cands = out
            gathered.update(cands)
        return (gathered, stats) if return_stats else gathered

    # -------------------------------------------------------------- match --
    def match(self, q, **kw):
        return self.match_many([q], **kw)[0]

    def match_many(self, queries: list, return_stats: bool = False):
        """Scatter-gather exact matching; byte-identical per query to
        single-process ``engine.match_many`` (see module doc)."""
        eng = self.engine
        nq = len(queries)
        if nq == 0:
            return ([], []) if return_stats else []
        results: list = [None] * nq
        info: list = [{} for _ in range(nq)]
        canon = None
        miss = list(range(nq))
        if self.cache is not None:
            canon = [canonical_form(q) for q in queries]
            miss = []
            for qi, (perm, key) in enumerate(canon):
                ent = self.cache.get(key)
                if ent is not None:
                    results[qi] = remap_matches(ent.matches, perm)
                    info[qi] = {"cache_hit": True, "n_matches": len(results[qi])}
                else:
                    miss.append(qi)
        if miss:
            sub = [queries[qi] for qi in miss]
            sub_results, contributing, plans = self._match_scatter(sub)
            for k, qi in enumerate(miss):
                results[qi] = sub_results[k]
                info[qi] = {"cache_hit": False, "n_matches": len(sub_results[k])}
                if self.cache is not None:
                    q = queries[qi]
                    perm, key = canon[qi]
                    plan_hashes = {
                        int(hash_labels(q.labels[np.asarray(p, np.int64)][None, :])[0])
                        for p in plans[k].paths
                    }
                    self.cache.put(
                        key,
                        canonical_matches(sub_results[k], perm, q.n_vertices),
                        contributing[k],
                        plan_hashes,
                        eng.epoch,
                    )
        return (results, info) if return_stats else results

    def _match_scatter(self, queries: list):
        """The core scatter-gather pipeline for cache-miss queries:
        plan (shared plan cache; dr cost probes are their own scatter
        round) → scatter plan paths → assemble in ascending partition
        order (main rows then delta rows — ``_match_many_core``'s exact
        order) → join at the coordinator."""
        eng = self.engine
        cfg = eng.cfg
        nq = len(queries)
        n_models = len(eng.models)
        use_groups = cfg.index_kind == "grouped"
        gathered: dict = {}
        gstats: dict = {}
        probed: set = set()
        # ---- plans: replicate _match_many_core byte for byte ------------
        plan_group_size = cfg.group_size if (cfg.plan_weight == "dr" and use_groups) else 1
        cached_plans: list = [None] * nq
        weight_fns: list = [None] * nq
        if cfg.plan_weight == "dr":
            cached_plans = [eng._dr_plan_peek(q, plan_group_size) for q in queries]
            reqs = list(
                dict.fromkeys(
                    (qi, p)
                    for qi, q in enumerate(queries)
                    if cached_plans[qi] is None
                    for p in candidate_plan_paths(q, cfg.path_length)
                )
            )
            if reqs:
                out = self._scatter(queries, reqs, return_stats=use_groups)
                if use_groups:
                    cands, st = out
                    gstats.update(st)
                else:
                    cands = out
                gathered.update(cands)
                probed.update(reqs)
            gsz = max(cfg.group_size, 1)

            def make_weight_fn(qi):
                # same weights as the single-process dr cost model: the
                # gathered arrays ARE the memo/delta rows (grouped adds
                # surviving-group fan-outs from the ride-along stats)
                def weight_fn(p):
                    if use_groups:
                        w = sum(
                            gstats[(mi, qi, p)]["surviving_groups"]
                            for mi in range(n_models)
                            if (mi, qi, p) in gstats
                        )
                        w += sum(
                            -(-gathered[(mi, qi, p)][1].shape[0] // gsz)
                            for mi in range(n_models)
                            if (mi, qi, p) in gathered
                        )
                        return float(w)
                    return float(
                        sum(
                            gathered[(mi, qi, p)][0].shape[0]
                            + gathered[(mi, qi, p)][1].shape[0]
                            for mi in range(n_models)
                            if (mi, qi, p) in gathered
                        )
                    )

                return weight_fn

            weight_fns = [
                make_weight_fn(qi) if cached_plans[qi] is None else None
                for qi in range(nq)
            ]
        plans = [
            cached_plans[qi]
            if cached_plans[qi] is not None
            else eng._plan_cached(q, weight_fn=weight_fns[qi], group_size=plan_group_size)
            for qi, q in enumerate(queries)
        ]
        # ---- retrieval scatter: plan paths not already gathered ----------
        todo = list(
            dict.fromkeys(
                (qi, p)
                for qi, plan in enumerate(plans)
                for p in plan.paths
                if (qi, p) not in probed
            )
        )
        if todo:
            gathered.update(self._scatter(queries, todo))
            probed.update(todo)
        # ---- assembly: the single-process candidate order, exactly -------
        # host join: ascending mi, main rows then delta rows per partition
        # (_match_many_core's loop).  device join + stacked probe: the
        # engine's probe assembles mains on device in ascending SLOT
        # order with every partition's delta rows appended after — so
        # the coordinator mirrors that order for byte-identity there too.
        device_assembly = (
            cfg.join_impl == "device" and cfg.probe_impl == "stacked" and n_models > 0
        )
        if device_assembly:
            slot_of = eng.stacked_probe().stacked.slot_of
            main_order = sorted(range(n_models), key=lambda mi: int(slot_of[mi]))
        else:
            main_order = list(range(n_models))
        contributing: list = [set() for _ in range(nq)]
        per_query_cands: list = []
        for qi, plan in enumerate(plans):
            candidates: list = [[] for _ in plan.paths]
            for mi in main_order:
                for pi, p in enumerate(plan.paths):
                    ent = gathered.get((mi, qi, p))
                    if ent is None:
                        continue
                    main, dverts = ent
                    if main.shape[0]:
                        candidates[pi].append(main)
                        contributing[qi].add(mi)
                    if not device_assembly and dverts.shape[0]:
                        candidates[pi].append(dverts)
                        contributing[qi].add(mi)
            if device_assembly:
                for mi in range(n_models):
                    for pi, p in enumerate(plan.paths):
                        ent = gathered.get((mi, qi, p))
                        if ent is not None and ent[1].shape[0]:
                            candidates[pi].append(ent[1])
                            contributing[qi].add(mi)
            per_query_cands.append(
                [
                    np.concatenate(parts, axis=0)
                    if parts
                    else np.zeros((0, len(plan.paths[pi])), np.int32)
                    for pi, parts in enumerate(candidates)
                ]
            )
        # ---- join + refine at the coordinator ---------------------------
        if cfg.join_impl == "device":
            results = match_from_candidates_many(
                eng.graph, queries, [plan.paths for plan in plans], per_query_cands,
                induced=cfg.induced, join_impl="device", assume_unique=True,
            )
        else:
            results = [
                match_from_candidates(
                    eng.graph, q, plans[qi].paths, per_query_cands[qi],
                    induced=cfg.induced, join_impl="numpy", assume_unique=True,
                )
                for qi, q in enumerate(queries)
            ]
        return results, contributing, plans

    # ------------------------------------------------------------ updates --
    def apply_updates(self, updates, **kw) -> dict:
        """Updates land on the engine; invalidation routes through the
        sharded cache so evictions stay on the mutated partitions' owner
        shards.  (Process mode: every process applies the same update
        stream — deterministic replicas stay identical.)"""
        if self.durability is not None:
            if not isinstance(updates, (list, tuple)):
                updates = [updates]
            self.durability.log_epoch(
                self.engine.epoch + 1,
                list(updates),
                kw.get("strategy", "delta"),
                kw.get("compaction", "inline"),
            )
        summary = self.engine.apply_updates(updates, **kw)
        if self.durability is not None:
            self.durability.after_apply(self.engine)
        if self.cache is not None:
            last = self.engine.epoch_fresh() or {}
            if last.get("strategy") == "rebuild":
                self.cache.clear()
            else:
                mutated = last.get("mutated") or {}
                if mutated:
                    self.cache.invalidate(mutated)
        return summary

    # --------------------------------------------------------- blue-green --
    def rebuild_generation(self, store=None, max_attempts: int = 3) -> dict:
        """Blue-green index swap: snapshot → build the next generation
        off the serving path → persist it as versioned artifacts
        (``store``: a dist/checkpoint.py ``CheckpointManager``; one
        ``step_<generation>.npz`` per generation) → version-checked
        atomic install.  An update landing mid-build fails the install;
        re-snapshot and retry, bounded by ``max_attempts``."""
        eng = self.engine
        snap = None
        for _ in range(max(int(max_attempts), 1)):
            snap = eng.prepare_generation()
            built = eng.build_generation(snap)
            if store is not None:
                store.save(int(snap["generation"]), _generation_artifacts(built))
            if eng.install_generation(snap, built):
                _M_CLUSTER.labels(event="generation_installed").inc()
                if EVENTS.active:
                    EVENTS.emit(
                        "blue_green_swap",
                        generation=int(snap["generation"]),
                        installed=True,
                    )
                return {"generation": int(snap["generation"]), "installed": True}
            _M_CLUSTER.labels(event="generation_install_conflict").inc()
        if EVENTS.active:
            EVENTS.emit(
                "blue_green_swap",
                generation=int(snap["generation"]),
                installed=False,
            )
        return {"generation": int(snap["generation"]), "installed": False}

    def load_generation(self, store, generation: int | None = None) -> dict:
        """Verified read-back of persisted generation artifacts.

        ``store.restore_arrays`` runs the digest-manifest verification
        (dist/checkpoint.py) — a torn or bit-flipped artifact raises
        ``CorruptCheckpointError`` instead of installing a wrong index;
        ``generation=None`` falls back to the newest *valid* step.  The
        arrays re-pack through ``build_index`` exactly as
        ``_generation_artifacts`` promises → ``{"generation", "indexes"}``.
        """
        from ..core.grouping import attach_groups
        from ..core.index import build_index

        arrays, gen = store.restore_arrays(generation)
        eng = self.engine
        indexes = []
        for mi, m in enumerate(eng.models):
            paths = np.asarray(arrays[f"p{mi}_paths"], np.int32)
            quantize = m.index.emb_q is not None
            ix = build_index(
                paths,
                np.asarray(arrays[f"p{mi}_emb"], np.float32),
                np.asarray(arrays[f"p{mi}_emb0"], np.float32),
                np.asarray(arrays[f"p{mi}_emb_multi"], np.float32),
                block_size=m.index.block_size,
                fanout=m.index.fanout,
                quantize=quantize,
                path_labels=eng.graph.labels[paths] if quantize and paths.size else None,
            )
            if m.index.groups is not None:
                attach_groups(ix, m.index.groups.group_size)
            indexes.append(ix)
        return {"generation": int(gen), "indexes": indexes}

    # ------------------------------------------------------------- status --
    def cluster_stats(self) -> dict:
        out = {
            "n_hosts": len(self.hosts),
            "placement": self.placement.as_dict() if self.placement else None,
            **self.stats,
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats_dict()
        return out

    def shutdown(self) -> None:
        """Stop remote worker loops (no-op for local hosts)."""
        for host in self.hosts:
            stop = getattr(host, "stop", None)
            if stop is not None:
                stop()


def _generation_artifacts(built: list) -> dict:
    """Flatten a built generation to plain arrays for the artifact
    store: per partition the packed paths + main/label/multi path
    embeddings — enough to re-pack the exact index via ``build_index``
    on restore (levels/groups/quantization are deterministic functions
    of these under the engine config)."""
    art = {}
    for mi, out in enumerate(built):
        ix = out["index"]
        art[f"p{mi}_paths"] = ix.paths
        art[f"p{mi}_emb"] = ix.emb
        art[f"p{mi}_emb0"] = ix.emb0
        art[f"p{mi}_emb_multi"] = ix.emb_multi
    return art
