"""Distributed substrate: sharding specs, mesh context, checkpointing,
pipeline parallelism.

Importing any ``repro.dist`` submodule installs a small compatibility
shim (`compat.install`) so code written against newer jax mesh APIs
(``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``)
also runs on the jax pinned in this container.
"""
from . import compat as _compat

_compat.install()
