"""GPipe pipeline parallelism over a ``pipe`` mesh axis (DESIGN §5).

Stage ``k`` lives on mesh slot ``k``; microbatches stream left→right via
``ppermute`` shifts.  Tick ``t``: stage 0 injects microbatch ``t`` (while
any remain), every stage applies its params to whatever activation just
arrived, and the last stage banks the finished microbatch ``t-(P-1)``.
``M + P - 1`` ticks drain the schedule; the bubble is the usual
``(P-1)/(M+P-1)`` fraction.  Output is bit-equal to applying the stages
sequentially (no collectives touch the math — verified by
tests/test_pipeline_parallel.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn, stage_params, xs, mesh, axis: str = "pipe"):
    """Run ``stage_fn`` over ``n_stages`` pipeline stages.

    stage_fn: (params_k, x) → y, same shape as x.
    stage_params: tree whose leaves lead with the stage dim (n_stages, ...).
    xs: (M, B, D) microbatches.
    Returns (M, B, D) = stage_{P-1}(… stage_0(xs) …).
    """
    n_stages = int(mesh.shape[axis])
    M = int(xs.shape[0])
    shift = [(k, k + 1) for k in range(n_stages - 1)]

    def local(w, xs_rep):
        w0 = jax.tree.map(lambda a: a[0], w)  # this device's stage params
        idx = jax.lax.axis_index(axis)
        y0 = jnp.zeros(xs_rep.shape[1:], xs_rep.dtype)
        outs0 = jnp.zeros_like(xs_rep)

        def tick(carry, t):
            prev_y, outs = carry
            recv = jax.lax.ppermute(prev_y, axis, shift)
            x_in = jnp.where(idx == 0, xs_rep[jnp.clip(t, 0, M - 1)], recv)
            y = stage_fn(w0, x_in)
            # bank finished microbatch (meaningful on the last stage only;
            # other stages write too, but their outs are never read)
            oi = jnp.maximum(t - (n_stages - 1), 0)
            banked = jax.lax.dynamic_update_index_in_dim(outs, y, oi, 0)
            outs = jnp.where(t >= n_stages - 1, banked, outs)
            return (y, outs), None

        (_, outs), _ = jax.lax.scan(tick, (y0, outs0), jnp.arange(M + n_stages - 1))
        return outs[None]  # (1, M, B, D) per device → (P, M, B, D) global

    w_specs = jax.tree.map(lambda _: P(axis), stage_params)
    f = shard_map(
        local, mesh=mesh, in_specs=(w_specs, P()), out_specs=P(axis), check_rep=False
    )
    return f(stage_params, xs)[-1]
