"""Cost-ranked partition→host placement (distributed GNN-PE, arXiv
2511.09052 §load balancing).

The cluster tier assigns every graph partition to an owning host.  The
distributed GNN-PE paper ranks partitions by an estimated workload cost
and places them greedily on the least-loaded host — classic LPT
(longest-processing-time) list scheduling, which carries Graham's
additive guarantee

    max_load  ≤  total_cost / n_hosts  +  max_partition_cost

without needing the (unknowable) optimal assignment: when the greedy
pass places the partition that ends up defining ``max_load``, every
other host already carries at least ``max_load − that partition's
cost``, so ``total ≥ n · (max_load − c) + c``.  ``Placement.bound``
exposes exactly this quantity and the balance property test asserts
``max_load ≤ bound`` on adversarially skewed cost sets.

Costs come from ``GnnPeEngine.partition_stats()`` — the stacked probe's
per-partition scanned leaf pairs (the dynamic probe-work signal), the
candidate rows each partition served, its live row count and its index
bytes.  Dynamic signals dominate once observed; a cold engine (no
probes yet) degrades to the static row/byte proxy, so placement is
always defined.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PartitionCost", "Placement", "partition_costs", "place_partitions", "load_bound"]


@dataclasses.dataclass(frozen=True)
class PartitionCost:
    """Scalar placement cost of one partition, plus its raw signals."""

    part_id: int
    cost: float
    leaf_pairs: int = 0
    probe_rows: int = 0
    rows: int = 0
    nbytes: int = 0


# weights over (leaf_pairs, probe_rows, rows, nbytes).  Scanned leaf
# pairs are the probe's actual work unit; candidate rows feed the join;
# live rows are the static stand-in before any probe ran; bytes break
# ties so two idle empty-ish partitions still order deterministically.
DEFAULT_WEIGHTS = (1.0, 4.0, 1.0, 1e-6)


def partition_costs(stats: list, weights: tuple = DEFAULT_WEIGHTS) -> list:
    """``GnnPeEngine.partition_stats()`` records → ``PartitionCost`` list."""
    w_lp, w_pr, w_rows, w_b = weights
    out = []
    for s in stats:
        lp = int(s.get("leaf_pairs", 0))
        pr = int(s.get("probe_rows", 0))
        rows = int(s.get("rows", 0))
        nb = int(s.get("nbytes", 0))
        out.append(
            PartitionCost(
                part_id=int(s["part_id"]),
                cost=w_lp * lp + w_pr * pr + w_rows * rows + w_b * nb,
                leaf_pairs=lp,
                probe_rows=pr,
                rows=rows,
                nbytes=nb,
            )
        )
    return out


def load_bound(costs: list, n_hosts: int) -> float:
    """Graham's additive LPT guarantee: ``total/n + max`` (see module doc)."""
    if not costs:
        return 0.0
    vals = [c.cost for c in costs]
    return sum(vals) / max(n_hosts, 1) + max(vals)


@dataclasses.dataclass
class Placement:
    """Partition→host assignment with its per-host load accounting.

    ``host_of[i]`` is the owning host of the partition at engine model
    index ``i`` (NOT ``part_id`` — the cluster tier addresses partitions
    the way the engine does, by model position).
    """

    host_of: np.ndarray  # (n_parts,) int64: model index -> host id
    loads: np.ndarray  # (n_hosts,) float64 assigned cost per host
    bound: float  # Graham bound the greedy assignment respects
    costs: list  # the PartitionCost inputs, engine model order

    @property
    def n_hosts(self) -> int:
        return int(self.loads.size)

    def owned(self, host: int) -> list:
        """Model indices owned by ``host``, ascending (probe order)."""
        return [int(i) for i in np.nonzero(self.host_of == host)[0]]

    def max_load(self) -> float:
        return float(self.loads.max()) if self.loads.size else 0.0

    def balanced(self) -> bool:
        """The testable LPT property: max host load within the bound."""
        return self.max_load() <= self.bound + 1e-9

    def as_dict(self) -> dict:
        return {
            "host_of": [int(h) for h in self.host_of],
            "loads": [float(x) for x in self.loads],
            "bound": float(self.bound),
            "max_load": self.max_load(),
            "balanced": self.balanced(),
        }


def place_partitions(costs: list, n_hosts: int) -> Placement:
    """Cost-ranked greedy placement (LPT): partitions sorted by cost
    descending (``part_id`` ascending on ties, so placement is
    deterministic), each assigned to the currently least-loaded host
    (lowest host id on ties).

    ``costs`` is in engine model order; the returned ``host_of`` is too.
    """
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    n = len(costs)
    host_of = np.zeros(n, np.int64)
    loads = np.zeros(n_hosts, np.float64)
    order = sorted(range(n), key=lambda i: (-costs[i].cost, costs[i].part_id))
    for i in order:
        h = int(np.argmin(loads))  # argmin takes the lowest id on ties
        host_of[i] = h
        loads[h] += costs[i].cost
    return Placement(
        host_of=host_of, loads=loads, bound=load_bound(costs, n_hosts), costs=list(costs)
    )
