"""Atomic, async, elastic, *verified* checkpointing.

Layout: one ``step_<n>.npz`` plus a ``step_<n>.manifest.json`` sidecar
per checkpoint under the manager's dir.
Atomicity: arrays are staged to ``*.tmp`` and ``os.replace``d into
place; the manifest is written (same tmp/replace discipline) only
*after* the npz is durable, then the directory is fsync'd — manifest
presence is the commit point, so a crash mid-write never leaves a
checkpoint that ``latest_step()`` would pick up.
Verification: the manifest records the npz byte size, a whole-file
sha256, and a per-leaf sha256/dtype/shape digest.  ``restore()``
re-checks all of them and raises :class:`CorruptCheckpointError` on any
mismatch; ``restore(step=None)``/``latest_step()`` simply skip invalid
steps (torn, bit-flipped, or manifest-less) and fall back to the newest
valid one.
Elasticity: ``restore(template, shardings=...)`` re-lays leaves onto any
target mesh via ``jax.device_put`` — the source topology is irrelevant
because the serialized form is plain host arrays.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager", "CorruptCheckpointError"]

_PREFIX = "step_"
_MANIFEST_FORMAT = 1
_DICT_KEY = re.compile(r"^\['([^']*)'\]$")


class CorruptCheckpointError(RuntimeError):
    """A checkpoint step failed manifest/digest verification."""


def _flatten(tree):
    """Leaves + stable string keys encoding the tree path."""
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(path) for path, _ in leaves_with_path]
    leaves = [leaf for _, leaf in leaves_with_path]
    return keys, leaves, treedef


def _leaf_digest(x: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(x).tobytes()).hexdigest()


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        # test/fault-injection hook: runs between the npz becoming durable
        # and the manifest commit (the window a crash leaves an
        # uncommitted — and therefore skipped — step)
        self._pre_commit = None

    # ------------------------------------------------------------ paths ---
    def _path(self, step: int) -> Path:
        return self.dir / f"{_PREFIX}{step}.npz"

    def _manifest_path(self, step: int) -> Path:
        return self.dir / f"{_PREFIX}{step}.manifest.json"

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.glob(f"{_PREFIX}*.npz"):
            try:
                steps.append(int(p.stem[len(_PREFIX):]))
            except ValueError:
                continue
        return sorted(steps)

    def valid_steps(self) -> list[int]:
        """Steps that pass manifest verification, ascending."""
        out = []
        for s in self.all_steps():
            try:
                self.verify_step(s)
            except CorruptCheckpointError:
                continue
            out.append(s)
        return out

    def latest_step(self) -> int | None:
        steps = self.valid_steps()
        return steps[-1] if steps else None

    # ----------------------------------------------------------- verify ---
    def verify_step(self, step: int) -> dict:
        """Check manifest presence + whole-file digest; return the manifest.

        Raises :class:`CorruptCheckpointError` on a missing step, missing
        or unreadable manifest, size mismatch, or sha256 mismatch.
        """
        npz = self._path(step)
        mpath = self._manifest_path(step)
        if not npz.exists():
            raise CorruptCheckpointError(f"step {step}: missing {npz.name}")
        if not mpath.exists():
            raise CorruptCheckpointError(f"step {step}: uncommitted (no manifest)")
        try:
            manifest = json.loads(mpath.read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise CorruptCheckpointError(f"step {step}: unreadable manifest: {e}") from e
        data = npz.read_bytes()
        if len(data) != manifest.get("size"):
            raise CorruptCheckpointError(
                f"step {step}: size {len(data)} != manifest {manifest.get('size')}"
            )
        if hashlib.sha256(data).hexdigest() != manifest.get("sha256"):
            raise CorruptCheckpointError(f"step {step}: file sha256 mismatch")
        return manifest

    # ------------------------------------------------------------- save ---
    def save(self, step: int, state) -> None:
        keys, leaves, _ = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self._write(step, keys, host)

    def save_async(self, step: int, state) -> None:
        """Snapshot to host, then write on a background thread."""
        keys, leaves, _ = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, keys, host), daemon=True
        )
        self._thread.start()

    def _write(self, step: int, keys: list, host: list) -> None:
        arrays = {f"arr_{i}": x for i, x in enumerate(host)}
        arrays["__keys__"] = np.asarray(json.dumps(keys))
        final = self._path(step)
        tmp = final.with_suffix(final.suffix + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        data = tmp.read_bytes()
        os.replace(tmp, final)
        if self._pre_commit is not None:
            self._pre_commit()
        manifest = {
            "format": _MANIFEST_FORMAT,
            "step": int(step),
            "npz": final.name,
            "size": len(data),
            "sha256": hashlib.sha256(data).hexdigest(),
            "leaves": {
                k: {"sha256": _leaf_digest(x), "dtype": str(x.dtype), "shape": list(x.shape)}
                for k, x in zip(keys, host)
            },
        }
        mfinal = self._manifest_path(step)
        mtmp = mfinal.with_suffix(mfinal.suffix + ".tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, mfinal)
        # dir fsync pins both renames — after this, the step survives a
        # power cut; before it, verify_step() treats the step as absent
        dfd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        self._gc()

    def wait(self) -> None:
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            for p in (self._path(s), self._manifest_path(s)):
                try:
                    p.unlink()
                except FileNotFoundError:
                    pass

    # ------------------------------------------------------------- load ---
    def _load_verified(self, step: int) -> tuple[list, list]:
        """(keys, arrays) of a step, after whole-file + per-leaf checks."""
        manifest = self.verify_step(step)
        with np.load(self._path(step)) as z:
            saved_keys = json.loads(str(z["__keys__"]))
            saved = [z[f"arr_{i}"] for i in range(len(saved_keys))]
        want = manifest.get("leaves", {})
        if sorted(want) != sorted(saved_keys):
            raise CorruptCheckpointError(f"step {step}: leaf keys differ from manifest")
        for k, arr in zip(saved_keys, saved):
            rec = want[k]
            if str(arr.dtype) != rec["dtype"] or list(arr.shape) != rec["shape"]:
                raise CorruptCheckpointError(f"step {step}: leaf {k} dtype/shape mismatch")
            if _leaf_digest(arr) != rec["sha256"]:
                raise CorruptCheckpointError(f"step {step}: leaf {k} digest mismatch")
        return saved_keys, saved

    def _resolve_step(self, step: int | None) -> tuple[int, list, list]:
        if step is not None:
            keys, saved = self._load_verified(int(step))
            return int(step), keys, saved
        for s in reversed(self.all_steps()):
            try:
                keys, saved = self._load_verified(s)
                return s, keys, saved
            except CorruptCheckpointError:
                continue
        raise FileNotFoundError(f"no valid checkpoints under {self.dir}")

    def restore_arrays(self, step: int | None = None) -> tuple[dict, int]:
        """Verified load → ``({key: np.ndarray}, step)``, no template needed.

        Single-level dict keystrs (``['name']``) are unwrapped back to
        plain names, so a flat-dict ``save()`` roundtrips symmetrically.
        """
        self.wait()
        step, keys, saved = self._resolve_step(step)
        out = {}
        for k, arr in zip(keys, saved):
            m = _DICT_KEY.match(k)
            out[m.group(1) if m else k] = arr
        return out, step

    def restore(self, template, step: int | None = None, shardings=None):
        """Load a checkpoint into ``template``'s tree structure.

        ``shardings``: optional tree (matching ``template``) of
        ``jax.sharding.Sharding`` — each restored leaf is ``device_put``
        onto it (the elastic path: target mesh ≠ source mesh).
        Returns ``(restored_tree, step)``.  An explicit ``step`` that
        fails verification raises :class:`CorruptCheckpointError`;
        ``step=None`` skips invalid steps.
        """
        self.wait()
        step, saved_keys, saved = self._resolve_step(step)
        keys, leaves, treedef = _flatten(template)
        if keys != saved_keys:
            raise ValueError(
                f"checkpoint tree mismatch: saved {saved_keys} vs template {keys}"
            )
        shard_leaves = [None] * len(leaves)
        if shardings is not None:
            s_keys, shard_leaves, _ = _flatten(shardings)
            if s_keys != keys:
                raise ValueError("shardings tree does not match template")
        out = []
        for key, tmpl, arr, shard in zip(keys, leaves, saved, shard_leaves):
            t = jnp.asarray(tmpl) if not hasattr(tmpl, "shape") else tmpl
            if tuple(t.shape) != tuple(arr.shape):
                raise ValueError(
                    f"shape mismatch at {key}: checkpoint {arr.shape} vs template {t.shape}"
                )
            arr = arr.astype(t.dtype) if hasattr(t, "dtype") else arr
            out.append(jax.device_put(arr, shard) if shard is not None else jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), int(step)
