"""Atomic, async, elastic checkpointing.

Layout: one ``step_<n>.npz`` per checkpoint under the manager's dir.
Atomicity: arrays are staged to ``*.tmp`` and ``os.replace``d into
place, so a crash mid-write never leaves a readable-but-torn file.
Elasticity: ``restore(template, shardings=...)`` re-lays leaves onto any
target mesh via ``jax.device_put`` — the source topology is irrelevant
because the serialized form is plain host arrays.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager"]

_PREFIX = "step_"


def _flatten(tree):
    """Leaves + stable string keys encoding the tree path."""
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(path) for path, _ in leaves_with_path]
    leaves = [leaf for _, leaf in leaves_with_path]
    return keys, leaves, treedef


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------ paths ---
    def _path(self, step: int) -> Path:
        return self.dir / f"{_PREFIX}{step}.npz"

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.glob(f"{_PREFIX}*.npz"):
            try:
                steps.append(int(p.stem[len(_PREFIX):]))
            except ValueError:
                continue
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- save ---
    def save(self, step: int, state) -> None:
        keys, leaves, _ = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self._write(step, keys, host)

    def save_async(self, step: int, state) -> None:
        """Snapshot to host, then write on a background thread."""
        keys, leaves, _ = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, keys, host), daemon=True
        )
        self._thread.start()

    def _write(self, step: int, keys: list, host: list) -> None:
        arrays = {f"arr_{i}": x for i, x in enumerate(host)}
        arrays["__keys__"] = np.asarray(json.dumps(keys))
        final = self._path(step)
        tmp = final.with_suffix(final.suffix + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        self._gc()

    def wait(self) -> None:
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            try:
                self._path(s).unlink()
            except FileNotFoundError:
                pass

    # ---------------------------------------------------------- restore ---
    def restore(self, template, step: int | None = None, shardings=None):
        """Load a checkpoint into ``template``'s tree structure.

        ``shardings``: optional tree (matching ``template``) of
        ``jax.sharding.Sharding`` — each restored leaf is ``device_put``
        onto it (the elastic path: target mesh ≠ source mesh).
        Returns ``(restored_tree, step)``.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        with np.load(self._path(step)) as z:
            saved_keys = json.loads(str(z["__keys__"]))
            saved = [z[f"arr_{i}"] for i in range(len(saved_keys))]
        keys, leaves, treedef = _flatten(template)
        if keys != saved_keys:
            raise ValueError(
                f"checkpoint tree mismatch: saved {saved_keys} vs template {keys}"
            )
        shard_leaves = [None] * len(leaves)
        if shardings is not None:
            s_keys, shard_leaves, _ = _flatten(shardings)
            if s_keys != keys:
                raise ValueError("shardings tree does not match template")
        out = []
        for key, tmpl, arr, shard in zip(keys, leaves, saved, shard_leaves):
            t = jnp.asarray(tmpl) if not hasattr(tmpl, "shape") else tmpl
            if tuple(t.shape) != tuple(arr.shape):
                raise ValueError(
                    f"shape mismatch at {key}: checkpoint {arr.shape} vs template {t.shape}"
                )
            arr = arr.astype(t.dtype) if hasattr(t, "dtype") else arr
            out.append(jax.device_put(arr, shard) if shard is not None else jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), int(step)
