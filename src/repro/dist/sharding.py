"""PartitionSpec rules for every param family + spec→sharding lowering.

``DP`` is the composite data-parallel axis ``("pod", "data")``: batch
dims shard over both the pod and the intra-pod data axis when present.
Specs are written against the *largest* mesh (pod × data × model);
``to_shardings`` filters out axis names a given mesh doesn't carry, so
the same spec tree drives single-pod, multi-pod and test meshes.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "DP",
    "filter_spec",
    "lm_param_specs",
    "recsys_param_specs",
    "replicated_specs",
    "to_shardings",
]

# composite data-parallel axis: batch shards over pod × data when available
DP = ("pod", "data")


def _filter_entry(entry, names: frozenset):
    """Drop mesh-absent axis names from one PartitionSpec entry."""
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in names else None
    kept = tuple(a for a in entry if a in names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def filter_spec(spec: P, mesh) -> P:
    """Restrict ``spec`` to the axis names ``mesh`` actually has."""
    names = frozenset(mesh.axis_names)
    return P(*(_filter_entry(e, names) for e in spec))


def to_shardings(mesh, pspecs):
    """PartitionSpec tree → NamedSharding tree on ``mesh`` (axis-filtered)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, filter_spec(s, mesh)),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def replicated_specs(params):
    """Fully replicated spec tree (small models, per-partition GNNs)."""
    return jax.tree.map(lambda _: P(), params)


def _spec_for_lm_leaf(path: str, leaf, fsdp: bool) -> P:
    """Megatron-style TP rules by param name; optional FSDP over data.

    Column-parallel (shard the output dim on "model"): wq/wk/wv, w1/w3,
    MoE up-projections, lm_head.  Row-parallel (shard the input dim):
    wo, w2, MoE down-projection.  Embedding shards the vocab dim.
    MoE expert tables keep the expert dim on "model" (expert parallel).
    """
    nd = leaf.ndim
    if nd <= 1:
        return P()  # norms, biases: replicated
    lead = ("data",) if fsdp else ()

    def col():  # shard last dim on model
        mid = (None,) * (nd - 2)
        first = ("data" if fsdp else None,)
        return P(*(first + mid + ("model",)))

    def row():  # shard second-to-last... for 2D: (model, data|None)
        mid = (None,) * (nd - 2)
        return P(*(("model",) + mid + (("data",) if fsdp else (None,))))

    name = path.split("/")[-1]
    if name in ("router", "shared_w1", "shared_w3", "shared_w2"):
        return P(*([None] * nd))
    if "moe" in path:
        # expert parallel: the expert dim leads (under a stacked-layer dim)
        spec = [None] * nd
        spec[0] = "model"
        if fsdp and nd >= 3:
            spec[1] = "data"
        return P(*spec)
    if name in ("wq", "wk", "wv", "w1", "w3", "w_dkv", "w_krope", "lm_head"):
        return col()
    if name in ("wo", "w2", "w_uk", "w_uv"):
        return row()
    if name == "embed":
        return P("model", *([None] * (nd - 1)))
    del lead
    return P(*([None] * nd))


def _walk(tree, path=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{path}/{k}" if path else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{path}/{i}")
    else:
        yield path, tree


def _rebuild(tree, leaves_iter):
    if isinstance(tree, dict):
        return {k: _rebuild(v, leaves_iter) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_rebuild(v, leaves_iter) for v in tree]
        return out if isinstance(tree, list) else tuple(out)
    return next(leaves_iter)


def lm_param_specs(params, fsdp: bool = False):
    """Transformer param tree → PartitionSpec tree (TP + optional FSDP).

    The stacked ``layers`` subtree carries a leading scan dim which is
    never sharded; the per-name rule applies to the trailing dims.
    """

    def spec_for(path, leaf):
        in_stack = path.startswith("layers/") or "/layers/" in path or path == "layers"
        if in_stack and leaf.ndim >= 1:
            inner = _spec_for_lm_leaf(path, _Shaped(leaf.shape[1:]), fsdp)
            return P(None, *inner)
        return _spec_for_lm_leaf(path, leaf, fsdp)

    specs = [spec_for(p, l) for p, l in _walk(params)]
    return _rebuild(params, iter(specs))


class _Shaped:
    """Shape-only stand-in so stacked leaves reuse the per-leaf rule."""

    def __init__(self, shape):
        self.shape = tuple(shape)

    @property
    def ndim(self):
        return len(self.shape)


def recsys_param_specs(params):
    """DCN specs: embedding tables model-parallel over the field dim
    (the tables dominate bytes); dense cross/MLP layers replicated."""

    def spec_for(path, leaf):
        if path.split("/")[0] == "tables":
            return P("model", *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    specs = [spec_for(p, l) for p, l in _walk(params)]
    return _rebuild(params, iter(specs))
