"""Mesh context + in-graph sharding hints.

``use_mesh(mesh)`` scopes a global mesh; ``maybe_shard(x, *entries)``
applies ``with_sharding_constraint`` against that mesh (axis-filtered),
and is an exact no-op when no mesh is active — model code calls it
unconditionally and stays runnable on a single device.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import filter_spec

__all__ = ["use_mesh", "current_mesh", "maybe_shard"]

_state = threading.local()


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for ``maybe_shard`` calls in this thread."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def maybe_shard(x, *entries):
    """Constrain ``x`` to ``P(*entries)`` if a mesh is active, else no-op.

    Entries follow PartitionSpec syntax (str | tuple of str | None) and
    may name axes the active mesh doesn't have — those are dropped, so
    specs written for the pod×data×model production mesh run unchanged
    on test meshes.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = filter_spec(P(*entries), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
