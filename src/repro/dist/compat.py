"""Compatibility shims for older jax releases.

The codebase (and its tests) target the current jax mesh API:

  * ``jax.sharding.AxisType`` enum,
  * ``jax.make_mesh(shape, names, axis_types=...)``.

On the jax pinned in this container (0.4.x) neither exists.  Rather than
fork every call site, ``install()`` grafts no-op equivalents onto jax:
``AxisType`` becomes a plain enum and ``make_mesh`` accepts and ignores
``axis_types`` (0.4.x meshes are implicitly "auto").  Installing is
idempotent and does nothing on jax versions that already provide them.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax

__all__ = ["install"]


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
            if check_vma is not None and "check_rep" not in kw:
                kw["check_rep"] = check_vma  # renamed in newer jax
            return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic wrappers
        return
    if "axis_types" not in params:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            del axis_types  # 0.4.x meshes have no explicit axis types
            return orig(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh
