"""Partition-parallel index probe: one vmapped descent over the stacked
partition tensors, shard_map'd over a ``("part",)`` device mesh.

``core/stacked.py`` lays every partition's packed forest into dense
``(S, …)`` tensors; this module runs the online filter over them:

  1. **device stage** — the level-synchronous MBR descent (Lemmas
     4.3/4.4) and, for a grouped index, the GNN-PGE group-MBR scan, as
     ONE jitted ``jax.vmap`` over the partition axis.  With more than
     one device the vmapped body is wrapped in ``jax.shard_map`` over a
     ``("part",)`` mesh, so each device scans only its (size-balanced)
     slice of the partitions — the distributed GNN-PE follow-up's
     partition-sharded traversal;
  2. **leaf stage** — the surviving (partition, query, block/group)
     cells expand to member rows across ALL partitions at once
     (vectorized on the stacked layout, no per-partition Python loop),
     ride the conservative int8 + label-hash pre-filter, and settle in
     one fused ``dominance_scan_pairs`` call (NumPy reference behind
     ``use_pallas=False``) — exactly the loop probe's exact predicates,
     so row sets are identical per (partition, query).

Mask math matches ``query_index_batch_multi`` bit for bit: both compute
float32 ``bound ± eps`` compares, and the synthesized/padded bounds are
reject sentinels that never pass (see core/stacked.py).  The probe is a
drop-in for the loop traversal — ``GnnPeEngine`` selects it with
``probe_impl="stacked"`` — and ``PAIR_COUNTERS`` / per-query stats keep
the loop probe's semantics so cost models and benches read identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import index as index_mod
from ..core.index import quantize_query
from ..core.stacked import StackedIndex, build_stacked, restack_slot, stacked_masks_ref

__all__ = ["StackedProbe"]


def _pow2_at_least(n: int, floor: int = 8) -> int:
    out = floor
    while out < n:
        out *= 2
    return out


class StackedProbe:
    """Runs the two-level probe over a ``StackedIndex`` (see module doc).

    ``devices=None`` uses every local jax device; a single device runs
    plain ``jit(vmap(...))``, more than one shards the partition axis
    with ``shard_map`` over a ``("part",)`` mesh.

    ``leaf_pair_cap`` bounds the cross-partition leaf member-expansion:
    surviving (partition, query, block/group) cells expand to at most
    ~``cap`` (query, row) pairs per chunk, each chunk streaming through
    the pre-filter + fused exact scan before the next materializes — a
    pathological partition (huge surviving fan-out) costs extra kernel
    dispatches instead of host memory.  Results are identical for any
    cap; with the default no bench workload chunks at all.
    """

    def __init__(
        self,
        indexes: list,
        devices=None,
        stacked: StackedIndex | None = None,
        leaf_pair_cap: int = 1 << 21,
    ):
        if leaf_pair_cap < 1:
            raise ValueError(f"leaf_pair_cap must be >= 1, got {leaf_pair_cap}")
        # default to the LOCAL devices: under a multi-process
        # jax.distributed bootstrap each host probes its own shard of
        # the cluster — sharding over jax.devices() (global) would ask
        # for cross-process SPMD this probe never issues
        self.devices = list(devices) if devices is not None else list(jax.local_devices())
        self.leaf_pair_cap = int(leaf_pair_cap)
        n_dev = max(len(self.devices), 1)
        self.stacked = stacked if stacked is not None else build_stacked(indexes, n_shards=n_dev)
        self.mesh = (
            jax.make_mesh((n_dev,), ("part",), devices=self.devices) if n_dev > 1 else None
        )
        self._mask_fns: dict = {}
        # device-join support (probe_device): source indexes for the lazy
        # stacked paths tensor, jitted leaf-stage closures, and a counter
        # of host-side member expansions (0 stays 0 on the device path —
        # the bench gate's "no host round-trip" evidence)
        self._indexes = list(indexes)
        self._dev_leaf: dict | None = None
        self._leaf_fns: dict = {}
        self.host_expansions = 0
        # per-partition scanned (query, row) leaf pairs, engine model
        # order — the cluster tier's placement cost signal
        # (GnnPeEngine.partition_stats / dist/placement.py).  Cumulative
        # over the probe's lifetime, like PAIR_COUNTERS.
        self.part_leaf_pairs = np.zeros(self.stacked.n_parts, np.int64)
        self._refresh_device()

    def _refresh_device(self) -> None:
        """(Re)materialize the device-resident level/group bounds."""
        self._dev_levels = (
            tuple(self._put(x) for x in self.stacked.level_hi),
            tuple(self._put(x) for x in self.stacked.level_lo0),
            tuple(self._put(x) for x in self.stacked.level_hi0),
        )
        g = self.stacked.groups
        self._dev_groups = (
            (self._put(g.hi), self._put(g.lo0), self._put(g.hi0)) if g is not None else None
        )

    def update_slot(self, part_i: int, index) -> bool:
        """Elastic re-stacking after partition ``part_i`` compacted: only
        its shard slot is rewritten (core/stacked.py ``restack_slot``) and
        the device tensors refresh — the other partitions are never
        re-stacked.  Returns ``False`` when the slot layout cannot absorb
        the new index (level count grew); the caller rebuilds the probe."""
        slot = int(self.stacked.slot_of[part_i])
        if not restack_slot(self.stacked, slot, index):
            return False
        if part_i < len(self._indexes):
            self._indexes[part_i] = index
        self._dev_leaf = None  # leaf payload moved; rebuild lazily
        self._refresh_device()
        return True

    def _put(self, x):
        if self.mesh is not None:
            return jax.device_put(x, NamedSharding(self.mesh, P("part")))
        return jnp.asarray(x)

    # ------------------------------------------------------------------
    # device stage: vmapped (and sharded) dense descent + group scan
    # ------------------------------------------------------------------
    def _mask_fn(self, use_groups: bool, eps: float):
        key = (use_groups, float(eps))
        fn = self._mask_fns.get(key)
        if fn is not None:
            return fn
        fanout = self.stacked.fanout
        gpb = self.stacked.groups.gpb if use_groups else 0

        def slot_fn(levels, group_bounds, q_cat, q0):
            level_hi, level_lo0, level_hi0 = levels
            alive = None
            for hi, lo0, hi0 in zip(level_hi, level_lo0, level_hi0):
                m = (
                    jnp.all(q_cat[:, None, :] <= hi[None] + eps, axis=-1)
                    & jnp.all(q0[:, None, :] <= hi0[None] + eps, axis=-1)
                    & jnp.all(q0[:, None, :] >= lo0[None] - eps, axis=-1)
                )
                if alive is not None:
                    m = m & jnp.repeat(alive, fanout, axis=1)[:, : m.shape[1]]
                alive = m
            if not use_groups:
                return (alive,)
            g_hi, g_lo0, g_hi0 = group_bounds
            gkeep = (
                jnp.repeat(alive, gpb, axis=1)
                & jnp.all(q_cat[:, None, :] <= g_hi[None] + eps, axis=-1)
                & jnp.all(q0[:, None, :] <= g_hi0[None] + eps, axis=-1)
                & jnp.all(q0[:, None, :] >= g_lo0[None] - eps, axis=-1)
            )
            return (alive, gkeep)

        mapped = jax.vmap(slot_fn)
        if self.mesh is not None:
            mapped = jax.shard_map(
                mapped, mesh=self.mesh, in_specs=P("part"), out_specs=P("part")
            )
        fn = jax.jit(mapped)
        self._mask_fns[key] = fn
        return fn

    def _device_masks_dev(self, q_cat, q0, eps, use_groups):
        """(S, Q, Dcat/D0) query tensors → (alive, gkeep) DEVICE masks."""
        S, Q = q_cat.shape[:2]
        Qp = _pow2_at_least(Q)
        if Qp != Q:  # bucket Q: padded queries carry +inf and never survive
            q_cat = np.concatenate(
                [q_cat, np.full((S, Qp - Q, q_cat.shape[2]), np.inf, np.float32)], axis=1
            )
            q0 = np.concatenate([q0, np.zeros((S, Qp - Q, q0.shape[2]), np.float32)], axis=1)
        group_bounds = self._dev_groups if use_groups else None
        out = self._mask_fn(use_groups, eps)(
            self._dev_levels, group_bounds, self._put(q_cat), self._put(q0)
        )
        alive = out[0][:, :Q]
        gkeep = out[1][:, :Q] if use_groups else None
        return alive, gkeep

    def _device_masks(self, q_cat, q0, eps, use_groups, device_stage):
        """(S, Q, Dcat/D0) query tensors → (alive, gkeep) numpy masks."""
        if device_stage == "numpy":
            return stacked_masks_ref(self.stacked, q_cat, q0, eps, use_groups)
        alive, gkeep = self._device_masks_dev(q_cat, q0, eps, use_groups)
        return np.asarray(alive), (np.asarray(gkeep) if use_groups else None)

    # ------------------------------------------------------------------
    # full probe: device masks → cross-partition leaf stage
    # ------------------------------------------------------------------
    def probe(
        self,
        q_emb: np.ndarray,  # (n_parts, Q, D) per-partition query embeddings
        q_emb0: np.ndarray,  # (n_parts, Q, D0)
        q_multi: np.ndarray | None = None,  # (n_gnn, n_parts, Q, D)
        q_label_hash: np.ndarray | None = None,  # (Q,) int64, shared
        eps: float = 1e-6,
        use_groups: bool = False,
        use_pallas: bool = True,
        return_stats: bool = False,
        device_stage: str = "jit",
    ):
        """Candidate rows for Q query paths against every partition.

        Returns a list (per partition, engine order) of lists (per
        query) of int64 row arrays — the same rows, in the same order,
        as ``query_index_batch_multi`` over the source indexes; with
        ``return_stats``, also the per-partition per-query stats dicts.
        """
        st = self.stacked
        if use_groups and st.groups is None and int(st.n_paths.sum()) > 0:
            raise ValueError(
                "use_groups=True needs the PackedGroupIndex sidecar — "
                "run core.grouping.attach_groups(index, group_size) first"
            )
        q_emb = np.asarray(q_emb, np.float32)
        q_emb0 = np.asarray(q_emb0, np.float32)
        n_parts, Q = q_emb.shape[:2]
        if n_parts != st.n_parts:
            raise ValueError(f"expected {st.n_parts} partitions, got {n_parts}")
        if Q == 0:
            results = [[] for _ in range(n_parts)]
            return (results, [[] for _ in range(n_parts)]) if return_stats else results
        if int(st.n_paths.sum()) == 0:
            # every partition is empty (zero length-L paths): the loop probe
            # returns empty row sets, so the stacked probe must too — even
            # under use_groups, where no sidecar could have been stacked
            results = [
                [np.zeros((0,), np.int64) for _ in range(Q)] for _ in range(n_parts)
            ]
            if not return_stats:
                return results
            zero = (
                {"scanned_blocks": 0, "scanned_groups": 0,
                 "surviving_groups": 0, "scanned_paths": 0}
                if use_groups
                else {"scanned_blocks": 0, "scanned_paths": 0}
            )
            return results, [[dict(zero) for _ in range(Q)] for _ in range(n_parts)]
        parts = [q_emb] + (
            [np.asarray(q_multi[i], np.float32) for i in range(st.n_gnn)] if st.n_gnn else []
        )
        cat = np.concatenate(parts, axis=2) if len(parts) > 1 else q_emb
        # scatter engine-order queries into shard-balanced slots
        S = st.n_slots
        q_cat = np.zeros((S, Q, cat.shape[2]), np.float32)
        q0 = np.zeros((S, Q, q_emb0.shape[2]), np.float32)
        q_cat[st.slot_of] = cat
        q0[st.slot_of] = q_emb0

        alive, gkeep = self._device_masks(q_cat, q0, eps, use_groups, device_stage)

        # ---- leaf stage: expand survivors across ALL partitions ----------
        # Cells (partition, query, block/group) are described by a start
        # row + member count WITHOUT materializing the rows, then expanded
        # in chunks of ≤ ~leaf_pair_cap pairs: each chunk streams through
        # the int8 pre-filter and the fused exact scan before the next
        # chunk exists, so a pathological partition cannot blow host
        # memory mid-probe.  Cell order is (pi, qi, ·)-major, so the
        # concatenated survivors stay combo-sorted for the final split.
        bs = st.block_size
        checked = member_rows = None
        if use_groups:
            g = st.groups
            B = alive.shape[2]
            groups_in_block = (g.count.reshape(S, B, g.gpb) > 0).sum(axis=2)
            checked = np.einsum("sqb,sb->sq", alive, groups_in_block)
            index_mod._GROUP_PAIRS.inc(int(checked.sum()))
            pi, qi, gi = np.nonzero(gkeep)
            starts = g.start[pi, gi]
            counts = g.count[pi, gi]
        else:
            pi, qi, bi = np.nonzero(alive)
            starts = bi.astype(np.int64) * bs
            counts = np.clip(st.n_paths[pi] - starts, 0, bs)
        total_pairs = int(counts.sum()) if counts.size else 0
        index_mod._LEAF_PAIRS.inc(total_pairs)
        if total_pairs:
            slot_lp = np.bincount(pi, weights=counts, minlength=S).astype(np.int64)
            self.part_leaf_pairs += slot_lp[st.slot_of]
        if return_stats and use_groups:
            member_rows = (
                np.bincount(pi * Q + qi, weights=counts, minlength=S * Q).astype(np.int64)
                if counts.size
                else np.zeros(S * Q, np.int64)
            )
        qq = quantize_query(q_cat) if st.emb_q is not None and total_pairs else None
        kept_rows: list = []
        kept_combo: list = []
        if total_pairs:
            cell_start = np.cumsum(counts) - counts
            chunk_of = cell_start // self.leaf_pair_cap  # nondecreasing
            n_chunks = int(chunk_of[-1]) + 1
            # chunks are contiguous cell ranges — slice via searchsorted
            # instead of one full boolean scan per chunk
            bounds = np.searchsorted(chunk_of, np.arange(n_chunks + 1))
        else:
            n_chunks = 0
        if n_chunks:  # (query, row) pairs materialize on the host below
            self.host_expansions += 1
        for c in range(n_chunks):
            lo, hi = int(bounds[c]), int(bounds[c + 1])
            cnt = counts[lo:hi]
            rows = index_mod._expand_segments(starts[lo:hi], cnt)
            pr = np.repeat(pi[lo:hi], cnt).astype(np.int64)
            qr = np.repeat(qi[lo:hi], cnt).astype(np.int64)
            combo = pr * Q + qr
            # conservative int8 + label-hash pre-filter (§Perf C1/C2)
            if qq is not None and rows.size:
                pre = np.all(qq[pr, qr] <= st.emb_q[pr, rows], axis=1)
                if st.label_hash is not None and q_label_hash is not None:
                    pre &= st.label_hash[pr, rows] == np.asarray(q_label_hash)[qr]
                rows, pr, qr, combo = rows[pre], pr[pre], qr[pre], combo[pre]
            # exact Lemma 4.1 + 4.2 verdicts — one fused pass per chunk
            if use_pallas:
                keep = index_mod._pairs_keep_mask(
                    q_cat[pr, qr], q0[pr, qr], st.emb_cat[pr, rows], st.emb0[pr, rows],
                    eps, use_pallas=True,
                )
            else:  # label short-circuit, like _pairs_keep_mask_numpy_lazy
                keep = np.all(np.abs(st.emb0[pr, rows] - q0[pr, qr]) <= eps, axis=1)
                sub = np.nonzero(keep)[0]
                if sub.size:
                    keep[sub] = np.all(
                        q_cat[pr[sub], qr[sub]] <= st.emb_cat[pr[sub], rows[sub]] + eps,
                        axis=1,
                    )
            kept_rows.append(rows[keep])
            kept_combo.append(combo[keep])
        rows_all = np.concatenate(kept_rows) if kept_rows else np.zeros(0, np.int64)
        combo_all = np.concatenate(kept_combo) if kept_combo else np.zeros(0, np.int64)
        splits = np.split(
            rows_all, np.cumsum(np.bincount(combo_all, minlength=S * Q))[:-1]
        )
        results = [
            [splits[int(st.slot_of[i]) * Q + qj] for qj in range(Q)]
            for i in range(n_parts)
        ]
        if not return_stats:
            return results
        scanned = alive.sum(axis=2)
        surviving = gkeep.sum(axis=2) if use_groups else None
        stats = []
        for i in range(n_parts):
            s = int(st.slot_of[i])
            if use_groups:
                stats.append(
                    [
                        {
                            "scanned_blocks": int(scanned[s, qj]),
                            "scanned_groups": int(checked[s, qj]),
                            "surviving_groups": int(surviving[s, qj]),
                            "scanned_paths": int(member_rows[s * Q + qj]),
                        }
                        for qj in range(Q)
                    ]
                )
            else:
                stats.append(
                    [
                        {
                            "scanned_blocks": int(scanned[s, qj]),
                            "scanned_paths": int(scanned[s, qj]) * bs,
                        }
                        for qj in range(Q)
                    ]
                )
        return results, stats

    # ------------------------------------------------------------------
    # device-resident candidate assembly (§device-join PR): the whole
    # leaf stage — cell expansion, pre-filter, exact pair scan, path-
    # vertex gather — runs as two jitted calls, and the per-probe
    # candidate VERTEX arrays stay on the device, ready for the jitted
    # merge join (core/matcher.py join_impl="device").  Only scalars
    # (cell/pair totals) and the per-probe row counts sync to the host.
    # ------------------------------------------------------------------
    def _leaf_tensors(self) -> dict:
        """Lazy device-resident leaf sidecar (incl. the stacked paths
        tensor, which ``StackedIndex`` itself does not carry)."""
        if self._dev_leaf is None:
            st = self.stacked
            p_max = st.emb_cat.shape[1]
            live = [ix for ix in self._indexes if ix.n_paths]
            L = live[0].paths.shape[1] if live else 2
            paths = np.zeros((st.n_slots, p_max, L), np.int32)
            for i, ix in enumerate(self._indexes):
                if ix.n_paths:
                    paths[int(st.slot_of[i]), : ix.n_paths] = ix.paths
            d = {
                "paths": jnp.asarray(paths),
                "emb_cat": jnp.asarray(st.emb_cat),
                "emb0": jnp.asarray(st.emb0),
                "n_paths": jnp.asarray(st.n_paths.astype(np.int32)),
                "emb_q": jnp.asarray(st.emb_q) if st.emb_q is not None else None,
            }
            if st.label_hash is not None:  # int64 → two int32 words (no x64)
                d["lh_hi"] = jnp.asarray((st.label_hash >> 32).astype(np.int32))
                d["lh_lo"] = jnp.asarray(
                    (st.label_hash & 0xFFFFFFFF).astype(np.uint32)
                )
            g = st.groups
            if g is not None:
                d["g_start"] = jnp.asarray(g.start.astype(np.int32))
                d["g_count"] = jnp.asarray(g.count.astype(np.int32))
                # groups present in each leaf block (level-1 accounting):
                # static per stacked identity, so built once here — and
                # its host twin serves the stats path without a refetch
                B = st.level_hi[-1].shape[1]
                gib = (g.count.reshape(st.n_slots, B, g.gpb) > 0).sum(axis=2)
                # host twin lives OUTSIDE the dict: the dict is a jit
                # operand, and a NumPy leaf would re-upload every call
                self._gib_host = gib.astype(np.int64)
                d["gib"] = jnp.asarray(gib.astype(np.int32))
            self._dev_leaf = d
        return self._dev_leaf

    def _cells_fn(self, use_groups: bool, cell_cap: int):
        """Jitted survivor-cell expansion: mask → (pi, qi, starts, counts)."""
        key = ("cells", use_groups, cell_cap)
        fn = self._leaf_fns.get(key)
        if fn is None:
            bs = self.stacked.block_size

            def cells(mask, n_cells, n_paths, g_start, g_count):
                pi, qi, ci = jnp.nonzero(mask, size=cell_cap, fill_value=0)
                cvalid = jnp.arange(cell_cap) < n_cells
                if use_groups:
                    starts = g_start[pi, ci]
                    counts = g_count[pi, ci]
                else:
                    starts = ci.astype(jnp.int32) * bs
                    counts = jnp.clip(n_paths[pi] - starts, 0, bs)
                counts = jnp.where(cvalid, counts, 0).astype(jnp.int32)
                return (
                    pi.astype(jnp.int32),
                    qi.astype(jnp.int32),
                    starts.astype(jnp.int32),
                    counts,
                    jnp.sum(counts),
                )

            fn = jax.jit(cells)
            self._leaf_fns[key] = fn
        return fn

    def _pairs_fn(self, pair_cap: int, quantized: bool, hashed: bool, has_live: bool, eps: float):
        """Jitted pair stage: expansion → pre-filter → exact scan →
        tombstone filter → vertex gather → probe-major compaction order."""
        key = ("pairs", pair_cap, quantized, hashed, has_live, float(eps))
        fn = self._leaf_fns.get(key)
        if fn is None:

            def pairs(pi, qi, starts, counts, total, q_cat, q0, qq, qh_hi, qh_lo, leaf, live):
                S, Q = q_cat.shape[:2]
                rows = jnp.repeat(starts, counts, total_repeat_length=pair_cap)
                ends = jnp.cumsum(counts)
                base = jnp.repeat(ends - counts, counts, total_repeat_length=pair_cap)
                rows = rows + (jnp.arange(pair_cap, dtype=jnp.int32) - base)
                pr = jnp.repeat(pi, counts, total_repeat_length=pair_cap)
                qr = jnp.repeat(qi, counts, total_repeat_length=pair_cap)
                keep = jnp.arange(pair_cap) < total
                if quantized:
                    keep &= jnp.all(qq[pr, qr] <= leaf["emb_q"][pr, rows], axis=1)
                    if hashed:
                        keep &= (leaf["lh_hi"][pr, rows] == qh_hi[qr]) & (
                            leaf["lh_lo"][pr, rows] == qh_lo[qr]
                        )
                # exact Lemma 4.1 + 4.2 predicates — same float32 ± eps
                # compares as the host leaf scan, so verdicts are identical
                keep &= jnp.all(jnp.abs(leaf["emb0"][pr, rows] - q0[pr, qr]) <= eps, axis=1)
                keep &= jnp.all(q_cat[pr, qr] <= leaf["emb_cat"][pr, rows] + eps, axis=1)
                if has_live:
                    keep &= live[pr, rows]
                verts = leaf["paths"][pr, rows]
                # probe-major compaction WITHOUT a sort: pairs arrive
                # slot-major with contiguous (slot, probe) groups, so the
                # output position of a kept pair is
                #   probe offset + kept pairs in earlier slots' groups
                #   + kept rank within its own group
                # — scatter-adds, cumsums and gathers only (XLA's CPU sort
                # would cost more than the whole rest of this stage)
                combo = pr * Q + qr
                kept_combo = jnp.where(keep, combo, S * Q)
                combo_counts = (
                    jnp.zeros((S * Q + 1,), jnp.int32).at[kept_combo].add(1)[: S * Q]
                )
                per_sb = combo_counts.reshape(S, Q)
                counts_b = per_sb.sum(axis=0)
                offs_b = jnp.cumsum(counts_b) - counts_b
                base_sb = offs_b[None, :] + (jnp.cumsum(per_sb, axis=0) - per_sb)
                first_idx = (
                    jnp.full((S * Q + 1,), pair_cap, jnp.int32)
                    .at[combo]
                    .min(jnp.arange(pair_cap, dtype=jnp.int32))[: S * Q]
                )
                ek = jnp.cumsum(keep.astype(jnp.int32)) - keep  # exclusive
                within = ek - ek[jnp.clip(first_idx[combo], 0, pair_cap - 1)]
                pos = base_sb.reshape(-1)[combo] + within
                pos = jnp.where(keep, pos, pair_cap)  # dropped: scatter-drop
                out = jnp.zeros((pair_cap, verts.shape[1]), jnp.int32)
                out = out.at[pos].set(verts, mode="drop")
                return out, counts_b, combo_counts

            fn = jax.jit(pairs)
            self._leaf_fns[key] = fn
        return fn

    def probe_device(
        self,
        q_emb: np.ndarray,  # (n_parts, Q, D)
        q_emb0: np.ndarray,  # (n_parts, Q, D0)
        q_multi: np.ndarray | None = None,  # (n_gnn, n_parts, Q, D)
        q_label_hash: np.ndarray | None = None,  # (Q,) int64, shared
        eps: float = 1e-6,
        use_groups: bool = False,
        use_pallas: bool = True,
        return_stats: bool = False,
        live_mask: np.ndarray | None = None,  # (S, P_max) bool; None = all live
    ):
        """Device-resident candidate assembly for Q probes.

        Returns ``(per_probe, part_counts[, stats])``:

          * ``per_probe[b]`` is ``(verts, count)`` — a DEVICE (count-
            prefixed) int32 array of candidate path VERTICES, already
            concatenated across every partition and filtered through
            ``live_mask`` — exactly the rows the host path would gather
            via ``index.paths[rows]``, never materialized on the host;
          * ``part_counts[mi, b]`` (host) — that probe's surviving row
            count per engine partition (cost models, cache scoping).

        The candidate sets equal ``probe`` + tombstone filtering per
        (partition, probe).  When the expansion would exceed
        ``leaf_pair_cap`` pairs the probe falls back to the chunked host
        path (counted in ``host_expansions``) and uploads the gathered
        vertices — identical results, bounded host memory.
        """
        st = self.stacked
        if use_groups and st.groups is None and int(st.n_paths.sum()) > 0:
            raise ValueError(
                "use_groups=True needs the PackedGroupIndex sidecar — "
                "run core.grouping.attach_groups(index, group_size) first"
            )
        q_emb = np.asarray(q_emb, np.float32)
        q_emb0 = np.asarray(q_emb0, np.float32)
        n_parts, Q = q_emb.shape[:2]
        if n_parts != st.n_parts:
            raise ValueError(f"expected {st.n_parts} partitions, got {n_parts}")
        L = self._indexes[0].paths.shape[1] if self._indexes else 2
        empty_b = (jnp.zeros((0, L), jnp.int32), 0)
        if Q == 0 or int(st.n_paths.sum()) == 0:
            per_b = [empty_b for _ in range(Q)]
            pc = np.zeros((n_parts, Q), np.int64)
            if not return_stats:
                return per_b, pc
            zero = (
                {"scanned_blocks": 0, "scanned_groups": 0,
                 "surviving_groups": 0, "scanned_paths": 0}
                if use_groups
                else {"scanned_blocks": 0, "scanned_paths": 0}
            )
            return per_b, pc, [[dict(zero) for _ in range(Q)] for _ in range(n_parts)]
        parts = [q_emb] + (
            [np.asarray(q_multi[i], np.float32) for i in range(st.n_gnn)] if st.n_gnn else []
        )
        cat = np.concatenate(parts, axis=2) if len(parts) > 1 else q_emb
        S = st.n_slots
        q_cat = np.zeros((S, Q, cat.shape[2]), np.float32)
        q0 = np.zeros((S, Q, q_emb0.shape[2]), np.float32)
        q_cat[st.slot_of] = cat
        q0[st.slot_of] = q_emb0

        alive, gkeep = self._device_masks_dev(q_cat, q0, eps, use_groups)
        mask = gkeep if use_groups else alive
        n_cells = int(jnp.sum(mask))
        leaf = self._leaf_tensors()
        dummy = jnp.zeros((1, 1), jnp.int32)
        g_start = leaf.get("g_start", dummy)
        g_count = leaf.get("g_count", dummy)
        if n_cells:
            cell_cap = _pow2_at_least(n_cells, 16)
            pi, qi, starts, counts, total_dev = self._cells_fn(use_groups, cell_cap)(
                mask, n_cells, leaf["n_paths"], g_start, g_count
            )
            total = int(total_dev)
        else:
            total = 0
        if total > self.leaf_pair_cap:
            # pathological fan-out: chunked host expansion (bounded host
            # memory), then one upload of the gathered vertex rows —
            # probe() maintains the pair counters itself
            return self._probe_device_fallback(
                q_emb, q_emb0, q_multi, q_label_hash, eps, use_groups,
                use_pallas, return_stats, live_mask,
            )
        index_mod._LEAF_PAIRS.inc(total)
        if total:
            # cells only (not pairs) cross back to the host here — the
            # same per-partition cost signal as the host path
            slot_lp = np.bincount(
                np.asarray(pi), weights=np.asarray(counts), minlength=S
            ).astype(np.int64)
            self.part_leaf_pairs += slot_lp[st.slot_of]
        if use_groups:
            # level-1 accounting matches the host probe: groups checked
            # per surviving (query, block) cell (gib cached in _leaf_tensors)
            checked_dev = jnp.einsum("sqb,sb->sq", alive.astype(jnp.int32), leaf["gib"])
            index_mod._GROUP_PAIRS.inc(int(jnp.sum(checked_dev)))
        if total == 0:
            per_b = [empty_b for _ in range(Q)]
            combo_counts = np.zeros(S * Q, np.int64)
        else:
            pair_cap = _pow2_at_least(total, 16)
            quantized = leaf["emb_q"] is not None
            hashed = quantized and "lh_hi" in leaf and q_label_hash is not None
            qq = (
                jnp.asarray(quantize_query(q_cat)) if quantized else jnp.zeros((1,), jnp.int8)
            )
            if hashed:
                qh = np.asarray(q_label_hash)
                qh_hi = jnp.asarray((qh >> 32).astype(np.int32))
                qh_lo = jnp.asarray((qh & 0xFFFFFFFF).astype(np.uint32))
            else:
                qh_hi = qh_lo = jnp.zeros((1,), jnp.int32)
            has_live = live_mask is not None
            live = jnp.asarray(live_mask) if has_live else jnp.zeros((1, 1), bool)
            verts_s, counts_b, combo_counts = self._pairs_fn(
                pair_cap, quantized, hashed, has_live, eps
            )(
                pi, qi, starts, counts, total_dev,
                jnp.asarray(q_cat), jnp.asarray(q0), qq, qh_hi, qh_lo, leaf, live,
            )
            counts_b = np.asarray(counts_b)
            combo_counts = np.asarray(combo_counts)
            offs = np.concatenate([[0], np.cumsum(counts_b)])
            per_b = [
                (verts_s[int(offs[b]) : int(offs[b]) + int(counts_b[b])], int(counts_b[b]))
                for b in range(Q)
            ]
        cc = combo_counts.reshape(S, Q)
        part_counts = cc[st.slot_of.astype(np.int64)]
        if not return_stats:
            return per_b, part_counts
        stats = self._device_probe_stats(alive, gkeep, use_groups, Q)
        return per_b, part_counts, stats

    def _device_probe_stats(self, alive, gkeep, use_groups, Q):
        """Per-(partition, probe) traversal stats, loop-probe semantics."""
        st = self.stacked
        alive_np = np.asarray(alive)
        scanned = alive_np.sum(axis=2)
        stats = []
        if use_groups:
            g = st.groups
            self._leaf_tensors()  # ensure the cached host twin exists
            gib = self._gib_host
            checked = np.einsum("sqb,sb->sq", alive_np, gib)
            gkeep_np = np.asarray(gkeep)
            surviving = gkeep_np.sum(axis=2)
            # member rows per (slot, probe): surviving groups' counts
            member = np.einsum("sqg,sg->sq", gkeep_np, g.count)
        for i in range(st.n_parts):
            s = int(st.slot_of[i])
            if use_groups:
                stats.append(
                    [
                        {
                            "scanned_blocks": int(scanned[s, qj]),
                            "scanned_groups": int(checked[s, qj]),
                            "surviving_groups": int(surviving[s, qj]),
                            "scanned_paths": int(member[s, qj]),
                        }
                        for qj in range(Q)
                    ]
                )
            else:
                stats.append(
                    [
                        {
                            "scanned_blocks": int(scanned[s, qj]),
                            "scanned_paths": int(scanned[s, qj]) * st.block_size,
                        }
                        for qj in range(Q)
                    ]
                )
        return stats

    def _probe_device_fallback(
        self, q_emb, q_emb0, q_multi, q_label_hash, eps, use_groups,
        use_pallas, return_stats, live_mask,
    ):
        """Chunked host path + one device upload (identical candidates)."""
        st = self.stacked
        out = self.probe(
            q_emb, q_emb0, q_multi, q_label_hash=q_label_hash, eps=eps,
            use_groups=use_groups, use_pallas=use_pallas, return_stats=return_stats,
        )
        results, stats = out if return_stats else (out, None)
        n_parts = st.n_parts
        Q = q_emb.shape[1]
        L = self._indexes[0].paths.shape[1] if self._indexes else 2
        lm = np.asarray(live_mask) if live_mask is not None else None
        per_b = []
        part_counts = np.zeros((n_parts, Q), np.int64)
        for b in range(Q):
            chunks = []
            for mi in range(n_parts):
                rows = results[mi][b]
                if lm is not None and rows.size:
                    rows = rows[lm[int(st.slot_of[mi]), rows]]
                part_counts[mi, b] = rows.size
                if rows.size:
                    chunks.append(self._indexes[mi].paths[rows])
            verts = (
                np.concatenate(chunks, axis=0).astype(np.int32)
                if chunks
                else np.zeros((0, L), np.int32)
            )
            per_b.append((jnp.asarray(verts), int(verts.shape[0])))
        if return_stats:
            return per_b, part_counts, stats
        return per_b, part_counts
