"""Partition-parallel index probe: one vmapped descent over the stacked
partition tensors, shard_map'd over a ``("part",)`` device mesh.

``core/stacked.py`` lays every partition's packed forest into dense
``(S, …)`` tensors; this module runs the online filter over them:

  1. **device stage** — the level-synchronous MBR descent (Lemmas
     4.3/4.4) and, for a grouped index, the GNN-PGE group-MBR scan, as
     ONE jitted ``jax.vmap`` over the partition axis.  With more than
     one device the vmapped body is wrapped in ``jax.shard_map`` over a
     ``("part",)`` mesh, so each device scans only its (size-balanced)
     slice of the partitions — the distributed GNN-PE follow-up's
     partition-sharded traversal;
  2. **leaf stage** — the surviving (partition, query, block/group)
     cells expand to member rows across ALL partitions at once
     (vectorized on the stacked layout, no per-partition Python loop),
     ride the conservative int8 + label-hash pre-filter, and settle in
     one fused ``dominance_scan_pairs`` call (NumPy reference behind
     ``use_pallas=False``) — exactly the loop probe's exact predicates,
     so row sets are identical per (partition, query).

Mask math matches ``query_index_batch_multi`` bit for bit: both compute
float32 ``bound ± eps`` compares, and the synthesized/padded bounds are
reject sentinels that never pass (see core/stacked.py).  The probe is a
drop-in for the loop traversal — ``GnnPeEngine`` selects it with
``probe_impl="stacked"`` — and ``PAIR_COUNTERS`` / per-query stats keep
the loop probe's semantics so cost models and benches read identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import index as index_mod
from ..core.index import quantize_query
from ..core.stacked import StackedIndex, build_stacked, restack_slot, stacked_masks_ref

__all__ = ["StackedProbe"]


def _pow2_at_least(n: int, floor: int = 8) -> int:
    out = floor
    while out < n:
        out *= 2
    return out


class StackedProbe:
    """Runs the two-level probe over a ``StackedIndex`` (see module doc).

    ``devices=None`` uses every local jax device; a single device runs
    plain ``jit(vmap(...))``, more than one shards the partition axis
    with ``shard_map`` over a ``("part",)`` mesh.

    ``leaf_pair_cap`` bounds the cross-partition leaf member-expansion:
    surviving (partition, query, block/group) cells expand to at most
    ~``cap`` (query, row) pairs per chunk, each chunk streaming through
    the pre-filter + fused exact scan before the next materializes — a
    pathological partition (huge surviving fan-out) costs extra kernel
    dispatches instead of host memory.  Results are identical for any
    cap; with the default no bench workload chunks at all.
    """

    def __init__(
        self,
        indexes: list,
        devices=None,
        stacked: StackedIndex | None = None,
        leaf_pair_cap: int = 1 << 21,
    ):
        if leaf_pair_cap < 1:
            raise ValueError(f"leaf_pair_cap must be >= 1, got {leaf_pair_cap}")
        self.devices = list(devices) if devices is not None else list(jax.devices())
        self.leaf_pair_cap = int(leaf_pair_cap)
        n_dev = max(len(self.devices), 1)
        self.stacked = stacked if stacked is not None else build_stacked(indexes, n_shards=n_dev)
        self.mesh = (
            jax.make_mesh((n_dev,), ("part",), devices=self.devices) if n_dev > 1 else None
        )
        self._mask_fns: dict = {}
        self._refresh_device()

    def _refresh_device(self) -> None:
        """(Re)materialize the device-resident level/group bounds."""
        self._dev_levels = (
            tuple(self._put(x) for x in self.stacked.level_hi),
            tuple(self._put(x) for x in self.stacked.level_lo0),
            tuple(self._put(x) for x in self.stacked.level_hi0),
        )
        g = self.stacked.groups
        self._dev_groups = (
            (self._put(g.hi), self._put(g.lo0), self._put(g.hi0)) if g is not None else None
        )

    def update_slot(self, part_i: int, index) -> bool:
        """Elastic re-stacking after partition ``part_i`` compacted: only
        its shard slot is rewritten (core/stacked.py ``restack_slot``) and
        the device tensors refresh — the other partitions are never
        re-stacked.  Returns ``False`` when the slot layout cannot absorb
        the new index (level count grew); the caller rebuilds the probe."""
        slot = int(self.stacked.slot_of[part_i])
        if not restack_slot(self.stacked, slot, index):
            return False
        self._refresh_device()
        return True

    def _put(self, x):
        if self.mesh is not None:
            return jax.device_put(x, NamedSharding(self.mesh, P("part")))
        return jnp.asarray(x)

    # ------------------------------------------------------------------
    # device stage: vmapped (and sharded) dense descent + group scan
    # ------------------------------------------------------------------
    def _mask_fn(self, use_groups: bool, eps: float):
        key = (use_groups, float(eps))
        fn = self._mask_fns.get(key)
        if fn is not None:
            return fn
        fanout = self.stacked.fanout
        gpb = self.stacked.groups.gpb if use_groups else 0

        def slot_fn(levels, group_bounds, q_cat, q0):
            level_hi, level_lo0, level_hi0 = levels
            alive = None
            for hi, lo0, hi0 in zip(level_hi, level_lo0, level_hi0):
                m = (
                    jnp.all(q_cat[:, None, :] <= hi[None] + eps, axis=-1)
                    & jnp.all(q0[:, None, :] <= hi0[None] + eps, axis=-1)
                    & jnp.all(q0[:, None, :] >= lo0[None] - eps, axis=-1)
                )
                if alive is not None:
                    m = m & jnp.repeat(alive, fanout, axis=1)[:, : m.shape[1]]
                alive = m
            if not use_groups:
                return (alive,)
            g_hi, g_lo0, g_hi0 = group_bounds
            gkeep = (
                jnp.repeat(alive, gpb, axis=1)
                & jnp.all(q_cat[:, None, :] <= g_hi[None] + eps, axis=-1)
                & jnp.all(q0[:, None, :] <= g_hi0[None] + eps, axis=-1)
                & jnp.all(q0[:, None, :] >= g_lo0[None] - eps, axis=-1)
            )
            return (alive, gkeep)

        mapped = jax.vmap(slot_fn)
        if self.mesh is not None:
            mapped = jax.shard_map(
                mapped, mesh=self.mesh, in_specs=P("part"), out_specs=P("part")
            )
        fn = jax.jit(mapped)
        self._mask_fns[key] = fn
        return fn

    def _device_masks(self, q_cat, q0, eps, use_groups, device_stage):
        """(S, Q, Dcat/D0) query tensors → (alive, gkeep) numpy masks."""
        if device_stage == "numpy":
            return stacked_masks_ref(self.stacked, q_cat, q0, eps, use_groups)
        S, Q = q_cat.shape[:2]
        Qp = _pow2_at_least(Q)
        if Qp != Q:  # bucket Q: padded queries carry +inf and never survive
            q_cat = np.concatenate(
                [q_cat, np.full((S, Qp - Q, q_cat.shape[2]), np.inf, np.float32)], axis=1
            )
            q0 = np.concatenate([q0, np.zeros((S, Qp - Q, q0.shape[2]), np.float32)], axis=1)
        group_bounds = self._dev_groups if use_groups else None
        out = self._mask_fn(use_groups, eps)(
            self._dev_levels, group_bounds, self._put(q_cat), self._put(q0)
        )
        alive = np.asarray(out[0])[:, :Q]
        gkeep = np.asarray(out[1])[:, :Q] if use_groups else None
        return alive, gkeep

    # ------------------------------------------------------------------
    # full probe: device masks → cross-partition leaf stage
    # ------------------------------------------------------------------
    def probe(
        self,
        q_emb: np.ndarray,  # (n_parts, Q, D) per-partition query embeddings
        q_emb0: np.ndarray,  # (n_parts, Q, D0)
        q_multi: np.ndarray | None = None,  # (n_gnn, n_parts, Q, D)
        q_label_hash: np.ndarray | None = None,  # (Q,) int64, shared
        eps: float = 1e-6,
        use_groups: bool = False,
        use_pallas: bool = True,
        return_stats: bool = False,
        device_stage: str = "jit",
    ):
        """Candidate rows for Q query paths against every partition.

        Returns a list (per partition, engine order) of lists (per
        query) of int64 row arrays — the same rows, in the same order,
        as ``query_index_batch_multi`` over the source indexes; with
        ``return_stats``, also the per-partition per-query stats dicts.
        """
        st = self.stacked
        if use_groups and st.groups is None and int(st.n_paths.sum()) > 0:
            raise ValueError(
                "use_groups=True needs the PackedGroupIndex sidecar — "
                "run core.grouping.attach_groups(index, group_size) first"
            )
        q_emb = np.asarray(q_emb, np.float32)
        q_emb0 = np.asarray(q_emb0, np.float32)
        n_parts, Q = q_emb.shape[:2]
        if n_parts != st.n_parts:
            raise ValueError(f"expected {st.n_parts} partitions, got {n_parts}")
        if Q == 0:
            results = [[] for _ in range(n_parts)]
            return (results, [[] for _ in range(n_parts)]) if return_stats else results
        if int(st.n_paths.sum()) == 0:
            # every partition is empty (zero length-L paths): the loop probe
            # returns empty row sets, so the stacked probe must too — even
            # under use_groups, where no sidecar could have been stacked
            results = [
                [np.zeros((0,), np.int64) for _ in range(Q)] for _ in range(n_parts)
            ]
            if not return_stats:
                return results
            zero = (
                {"scanned_blocks": 0, "scanned_groups": 0,
                 "surviving_groups": 0, "scanned_paths": 0}
                if use_groups
                else {"scanned_blocks": 0, "scanned_paths": 0}
            )
            return results, [[dict(zero) for _ in range(Q)] for _ in range(n_parts)]
        parts = [q_emb] + (
            [np.asarray(q_multi[i], np.float32) for i in range(st.n_gnn)] if st.n_gnn else []
        )
        cat = np.concatenate(parts, axis=2) if len(parts) > 1 else q_emb
        # scatter engine-order queries into shard-balanced slots
        S = st.n_slots
        q_cat = np.zeros((S, Q, cat.shape[2]), np.float32)
        q0 = np.zeros((S, Q, q_emb0.shape[2]), np.float32)
        q_cat[st.slot_of] = cat
        q0[st.slot_of] = q_emb0

        alive, gkeep = self._device_masks(q_cat, q0, eps, use_groups, device_stage)

        # ---- leaf stage: expand survivors across ALL partitions ----------
        # Cells (partition, query, block/group) are described by a start
        # row + member count WITHOUT materializing the rows, then expanded
        # in chunks of ≤ ~leaf_pair_cap pairs: each chunk streams through
        # the int8 pre-filter and the fused exact scan before the next
        # chunk exists, so a pathological partition cannot blow host
        # memory mid-probe.  Cell order is (pi, qi, ·)-major, so the
        # concatenated survivors stay combo-sorted for the final split.
        bs = st.block_size
        checked = member_rows = None
        if use_groups:
            g = st.groups
            B = alive.shape[2]
            groups_in_block = (g.count.reshape(S, B, g.gpb) > 0).sum(axis=2)
            checked = np.einsum("sqb,sb->sq", alive, groups_in_block)
            index_mod.PAIR_COUNTERS["group_pairs"] += int(checked.sum())
            pi, qi, gi = np.nonzero(gkeep)
            starts = g.start[pi, gi]
            counts = g.count[pi, gi]
        else:
            pi, qi, bi = np.nonzero(alive)
            starts = bi.astype(np.int64) * bs
            counts = np.clip(st.n_paths[pi] - starts, 0, bs)
        total_pairs = int(counts.sum()) if counts.size else 0
        index_mod.PAIR_COUNTERS["leaf_pairs"] += total_pairs
        if return_stats and use_groups:
            member_rows = (
                np.bincount(pi * Q + qi, weights=counts, minlength=S * Q).astype(np.int64)
                if counts.size
                else np.zeros(S * Q, np.int64)
            )
        qq = quantize_query(q_cat) if st.emb_q is not None and total_pairs else None
        kept_rows: list = []
        kept_combo: list = []
        if total_pairs:
            cell_start = np.cumsum(counts) - counts
            chunk_of = cell_start // self.leaf_pair_cap  # nondecreasing
            n_chunks = int(chunk_of[-1]) + 1
            # chunks are contiguous cell ranges — slice via searchsorted
            # instead of one full boolean scan per chunk
            bounds = np.searchsorted(chunk_of, np.arange(n_chunks + 1))
        else:
            n_chunks = 0
        for c in range(n_chunks):
            lo, hi = int(bounds[c]), int(bounds[c + 1])
            cnt = counts[lo:hi]
            rows = index_mod._expand_segments(starts[lo:hi], cnt)
            pr = np.repeat(pi[lo:hi], cnt).astype(np.int64)
            qr = np.repeat(qi[lo:hi], cnt).astype(np.int64)
            combo = pr * Q + qr
            # conservative int8 + label-hash pre-filter (§Perf C1/C2)
            if qq is not None and rows.size:
                pre = np.all(qq[pr, qr] <= st.emb_q[pr, rows], axis=1)
                if st.label_hash is not None and q_label_hash is not None:
                    pre &= st.label_hash[pr, rows] == np.asarray(q_label_hash)[qr]
                rows, pr, qr, combo = rows[pre], pr[pre], qr[pre], combo[pre]
            # exact Lemma 4.1 + 4.2 verdicts — one fused pass per chunk
            if use_pallas:
                keep = index_mod._pairs_keep_mask(
                    q_cat[pr, qr], q0[pr, qr], st.emb_cat[pr, rows], st.emb0[pr, rows],
                    eps, use_pallas=True,
                )
            else:  # label short-circuit, like _pairs_keep_mask_numpy_lazy
                keep = np.all(np.abs(st.emb0[pr, rows] - q0[pr, qr]) <= eps, axis=1)
                sub = np.nonzero(keep)[0]
                if sub.size:
                    keep[sub] = np.all(
                        q_cat[pr[sub], qr[sub]] <= st.emb_cat[pr[sub], rows[sub]] + eps,
                        axis=1,
                    )
            kept_rows.append(rows[keep])
            kept_combo.append(combo[keep])
        rows_all = np.concatenate(kept_rows) if kept_rows else np.zeros(0, np.int64)
        combo_all = np.concatenate(kept_combo) if kept_combo else np.zeros(0, np.int64)
        splits = np.split(
            rows_all, np.cumsum(np.bincount(combo_all, minlength=S * Q))[:-1]
        )
        results = [
            [splits[int(st.slot_of[i]) * Q + qj] for qj in range(Q)]
            for i in range(n_parts)
        ]
        if not return_stats:
            return results
        scanned = alive.sum(axis=2)
        surviving = gkeep.sum(axis=2) if use_groups else None
        stats = []
        for i in range(n_parts):
            s = int(st.slot_of[i])
            if use_groups:
                stats.append(
                    [
                        {
                            "scanned_blocks": int(scanned[s, qj]),
                            "scanned_groups": int(checked[s, qj]),
                            "surviving_groups": int(surviving[s, qj]),
                            "scanned_paths": int(member_rows[s * Q + qj]),
                        }
                        for qj in range(Q)
                    ]
                )
            else:
                stats.append(
                    [
                        {
                            "scanned_blocks": int(scanned[s, qj]),
                            "scanned_paths": int(scanned[s, qj]) * bs,
                        }
                        for qj in range(Q)
                    ]
                )
        return results, stats
