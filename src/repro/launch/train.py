"""Training launcher: ``--arch <id>`` end-to-end on the available devices.

    PYTHONPATH=src python -m repro.launch.train --arch gin-tu --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --smoke --steps 50

Full-scale configs target the production mesh (run under a TPU runtime or
with XLA_FLAGS host devices); ``--smoke`` runs the reduced config on
whatever devices exist — the loop, checkpointing, resumability, straggler
watchdog and metrics are the same code path either way.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import build_step, get_arch, init_params, make_batch, opt_init, resolve_config
from ..data.pipeline import LMSyntheticData, RecsysSyntheticData
from ..dist.checkpoint import CheckpointManager
from ..dist.context import use_mesh
from ..train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="defaults to the arch's training shape")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cell = arch.cell(args.shape) if args.shape else arch.shapes[0]
    cfg = resolve_config(arch, cell, smoke=args.smoke)
    mesh = None  # smoke path: single device; production: make_production_mesh()
    with use_mesh(mesh):
        params = init_params(arch, cfg, jax.random.PRNGKey(0))
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        print(f"[train] {arch.name}/{cell.name}: {n/1e6:.2f}M params, {args.steps} steps")
        opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(30, args.steps // 5), total_steps=args.steps)
        step_fn, takes_opt = build_step(arch, cell, cfg, mesh=mesh, opt_cfg=opt_cfg)
        assert takes_opt, f"{cell.name} is not a training shape"
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        opt = opt_init(params)

        # data: family-appropriate synthetic stream; fixed-graph families
        # reuse the (seed, step)-deterministic batch builder
        if arch.family == "lm":
            data = LMSyntheticData(cfg.vocab, *_lm_dims(cell, args.smoke), seed=0)
            batch_at = lambda s: data.batch_at(s)  # noqa: E731
        elif arch.family == "recsys":
            data = RecsysSyntheticData(cfg, batch=256 if args.smoke else 65536, seed=0)
            batch_at = lambda s: data.batch_at(s)  # noqa: E731
        else:
            fixed = make_batch(arch, cell, cfg, smoke=args.smoke)
            batch_at = lambda s: fixed  # noqa: E731

        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            state, start = ckpt.restore({"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start}")
        t0 = time.perf_counter()
        first_loss = None
        for s in range(start, args.steps):
            params, opt, metrics = step_fn(params, opt, batch_at(s))
            loss = float(metrics["loss"])
            if first_loss is None:
                first_loss = loss
            if s % args.log_every == 0:
                print(f"[train] step {s}: loss {loss:.4f} lr {float(metrics.get('lr', 0)):.2e}")
            if ckpt and (s + 1) % args.ckpt_every == 0:
                ckpt.save_async(s + 1, {"params": params, "opt": opt})
        if ckpt:
            ckpt.wait()
        dt = time.perf_counter() - t0
        print(f"[train] done: loss {first_loss:.4f} → {loss:.4f} in {dt:.1f}s "
              f"({(args.steps - start)/dt:.2f} steps/s)")


def _lm_dims(cell, smoke):
    if smoke:
        return 2, 64
    return cell.meta["global_batch"], cell.meta["seq_len"]


if __name__ == "__main__":
    main()
