"""Serving launcher — the paper's kind: exact subgraph-query service,
plus an LM decode mode exercising the same engine the dry-run lowers.

    PYTHONPATH=src python -m repro.launch.serve --mode gnnpe --n 2000 --requests 40
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch gemma3-1b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def serve_gnnpe(args):
    from ..core import GnnPeConfig, GnnPeEngine, vf2_match
    from ..graphs import newman_watts_strogatz, random_connected_query

    g = newman_watts_strogatz(args.n, k=4, p=0.1, n_labels=50, seed=0)
    print(f"[serve] building GNN-PE index: |V|={g.n_vertices} |E|={g.n_edges}")
    eng = GnnPeEngine(
        GnnPeConfig(
            encoder=args.encoder,
            n_partitions=max(args.n // 1000, 1),
            n_multi=2,
            quantize_index=args.quantize,
        )
    ).build(g)
    st = eng.offline_stats
    print(f"[serve] offline {st['total_time']:.1f}s, {st['n_paths']} paths, "
          f"{st['index_bytes']/1e6:.1f} MB")
    lat = []
    for r in range(args.requests):
        try:
            q = random_connected_query(g, int(np.random.default_rng(r).choice([5, 6, 8])), seed=r)
        except RuntimeError:
            continue
        t0 = time.perf_counter()
        matches = eng.match(q)
        lat.append(time.perf_counter() - t0)
        if r % 10 == 0:
            assert set(matches) == set(vf2_match(g, q)), "exactness violated!"
    ms = np.sort(np.asarray(lat)) * 1e3
    print(f"[serve] {len(lat)} queries: p50 {ms[len(ms)//2]:.1f}ms "
          f"p95 {ms[int(len(ms)*0.95)]:.1f}ms  throughput {len(lat)/sum(lat):.1f} qps")


def serve_lm(args):
    from ..configs import get_arch, init_params, resolve_config
    from ..serve.engine import DecodeEngine, ServeConfig

    arch = get_arch(args.arch)
    cfg = resolve_config(arch, arch.shapes[0], smoke=True)
    params = init_params(arch, cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(params, cfg, ServeConfig(max_batch=4, max_len=128, eos_token=-1))
    rng = np.random.default_rng(0)
    rids = [eng.submit(list(rng.integers(2, cfg.vocab, 8)), max_new=16) for _ in range(args.requests)]
    t0 = time.perf_counter()
    out = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"[serve] {len(out)}/{len(rids)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, continuous batching over 4 slots)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["gnnpe", "lm"], default="gnnpe")
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--encoder", default="monotone")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()
    if args.mode == "gnnpe":
        serve_gnnpe(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
