"""Roofline analysis from dry-run artifacts (assignment §Roofline).

Per (arch × shape) on the single-pod mesh:
    compute term    = HLO_FLOPs / peak_FLOP/s            (per-device both)
    memory term     = HLO_bytes / HBM_bw                 (TPU-fusion projection;
                      the CPU-fusion upper bound is reported alongside)
    collective term = Σ_kind collective_bytes·ring_factor / link_bw
with v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for LM training;
analytic per-family conventions for the others (documented in
EXPERIMENTS.md).  The ratio MODEL_FLOPS/HLO_FLOPs exposes remat/
redundancy waste.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
writes experiments/roofline.md + roofline.json.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

# effective wire multiplier per collective kind (ring algorithms)
RING_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather passes
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def model_flops(rec: dict) -> float:
    """Analytic useful FLOPs for the whole program, GLOBAL (all chips)."""
    arch, shape = rec["arch"], rec["shape"]
    n_act = rec.get("model_params_active", rec.get("model_params", 0))
    fam_lm = arch in (
        "minitron-4b",
        "gemma3-1b",
        "command-r-plus-104b",
        "deepseek-v2-lite-16b",
        "qwen3-moe-235b-a22b",
    )
    if fam_lm:
        meta = {
            "train_4k": (4096, 256),
            "prefill_32k": (32768, 32),
            "decode_32k": (32768, 128),
            "long_500k": (524288, 1),
        }[shape]
        S, B = meta
        if shape == "train_4k":
            return 6.0 * n_act * S * B  # fwd+bwd
        if shape == "prefill_32k":
            return 2.0 * n_act * S * B
        # decode: one token per sequence + attention over the cache
        return 2.0 * n_act * B  # attention O(S·d) term ≪ matmul for one token
    if arch == "dcn-v2":
        # dense compute = cross+MLP params × batch (tables are lookups)
        p_dense = 429 * 429 * 3 + 429 * 1024 + 1024 * 1024 + 1024 * 512
        batch = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144, "retrieval_cand": 1}[
            shape
        ]
        mult = 6.0 if shape == "train_batch" else 2.0
        f = mult * p_dense * batch
        if shape == "retrieval_cand":
            f += 2.0 * 1_000_000 * 64  # candidate dot products
        return f
    # GNN: params × nodes-evaluated convention
    p = rec.get("model_params", 0)
    nodes = {
        "full_graph_sm": 2708,
        "minibatch_lg": 1024 * 16 * 11,  # layered vertex sets
        "ogb_products": 2_449_029,
        "molecule": 128 * 30,
    }.get(shape, 1)
    return 6.0 * p * nodes


def load(dir_: Path, mesh: str) -> list:
    recs = []
    for p in sorted(dir_.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def terms(rec: dict) -> dict:
    t_comp = rec["flops"] / PEAK_FLOPS
    t_mem = rec["bytes_fused"] / HBM_BW
    t_mem_ub = rec["bytes"] / HBM_BW
    t_coll = sum(
        v * RING_FACTOR.get(k, 1.0) for k, v in rec.get("collective_bytes", {}).items()
    ) / ICI_BW
    dominant = max(
        [("compute", t_comp), ("memory", t_mem), ("collective", t_coll)], key=lambda kv: kv[1]
    )[0]
    mf = model_flops(rec)
    mf_dev = mf / max(rec.get("n_devices", 1), 1)
    useful = mf_dev / rec["flops"] if rec["flops"] else 0.0
    # roofline fraction: useful work time over the bound implied by the
    # dominant term (how close the step is to the hardware limit)
    t_bound = max(t_comp, t_mem, t_coll)
    frac = (mf_dev / PEAK_FLOPS) / t_bound if t_bound > 0 else 0.0
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "memory_ub_s": t_mem_ub,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": mf,
        "useful_ratio": useful,
        "roofline_frac": frac,
        "peak_gb": rec.get("memory", {}).get("peak_memory_in_bytes", 0) / 1e9,
    }


def fmt(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args()
    recs = load(Path(args.dir), "single")
    rows = []
    for rec in recs:
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "skip": rec["reason"]})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "skip": f"STATUS={rec['status']}"})
            continue
        t = terms(rec)
        rows.append({"arch": rec["arch"], "shape": rec["shape"], **t})
    lines = [
        "| arch | shape | compute | memory (ub) | collective | dominant | MODEL_FLOPS | useful | roofline | peak GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — | — |")
            continue
        lines.append(
            "| {arch} | {shape} | {c} | {m} ({mu}) | {k} | **{dom}** | {mf:.2e} | {ur:.2f} | {rf:.1%} | {pg:.1f} |".format(
                arch=r["arch"], shape=r["shape"], c=fmt(r["compute_s"]), m=fmt(r["memory_s"]),
                mu=fmt(r["memory_ub_s"]), k=fmt(r["collective_s"]), dom=r["dominant"],
                mf=r["model_flops_global"], ur=r["useful_ratio"], rf=r["roofline_frac"],
                pg=r["peak_gb"],
            )
        )
    out = Path(args.out)
    out.write_text("\n".join(lines) + "\n")
    Path(args.out.replace(".md", ".json")).write_text(json.dumps(rows, indent=1, default=str))
    print("\n".join(lines))


if __name__ == "__main__":
    main()
