"""Optimized-HLO cost analyzer with loop-trip accounting.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so a
scan-over-layers model under-reports FLOPs/bytes by ~L× and collective
bytes entirely.  This analyzer parses the post-SPMD optimized HLO text:

  * FLOPs: every ``dot`` (2·prod(out)·K, K = contracted extent) and
    ``convolution`` — recursing into fusions (``calls=``) and custom
    calls (``to_apply=``);
  * bytes: per top-level op, operands + outputs (post-fusion, so this
    approximates HBM traffic the way XLA's own model does);
  * collective bytes per kind (all-gather/all-reduce/reduce-scatter/
    all-to-all/collective-permute);
  * every quantity multiplied by ``while`` trip counts recovered from
    loop-condition constants.

Validated against jnp reference programs in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo", "parse_shape_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_dims(tok: str):
    """First shape in ``tok`` → (dtype, [dims]) or None."""
    m = _SHAPE_RE.search(tok)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def parse_shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_ELEMENTWISE = {
    "convert", "multiply", "add", "subtract", "divide", "maximum", "minimum",
    "broadcast", "compare", "select", "negate", "exponential", "rsqrt", "sqrt",
    "tanh", "log", "power", "and", "or", "xor", "not", "abs", "sign", "floor",
    "ceil", "clamp", "iota", "exponential-minus-one", "log-plus-one",
}


class _Comp:
    def __init__(self, name):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.bytes_fused = 0.0  # TPU projection: standalone elementwise fuses away
        self.coll = defaultdict(float)
        self.coll_count = 0
        self.calls = []  # (callee, multiplier_kind) kind: "call"|"while"
        self.shapes = {}  # %name -> shape text (lhs definitions + params)


def _split(hlo: str) -> dict:
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and "->" in line:
            name = line.split("(", 1)[0].strip()
            name = name.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = _Comp(name)
            comps[name] = cur
            # params are declared inline: %p.1: f32[...]
            for pm in re.finditer(r"%?([\w\.\-]+)\s*:\s*([\w\[\],\s\(\)\{\}]+?)(?:,|\)\s*->)", line):
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        # definition line: %name = SHAPE op(...)
        mdef = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)", line)
        if not mdef:
            continue
        lhs, rhs = mdef.group(1), mdef.group(2)
        cur.shapes[lhs] = rhs.split(" ", 1)[0] if rhs else ""
        # keep full rhs for analysis
        cur.shapes["__line__" + lhs] = rhs
    return comps


def _trip_counts(comps: dict) -> dict:
    """condition-computation name → trip count.

    A scan lowers to ``while(cond, body)`` where cond compares the counter
    to an s32 constant defined inside the cond computation (the compare
    itself may be fused into a wrapped_compare) — take the max constant.
    """
    trips = {}
    for name, comp in comps.items():
        consts = [0]
        for key, rhs in comp.shapes.items():
            if not key.startswith("__line__"):
                continue
            for m in re.finditer(r"constant\((\d+)\)", rhs):
                consts.append(int(m.group(1)))
        if max(consts) > 0:
            trips[name] = max(consts)
    return trips


def _analyze_comp(comp: _Comp):
    for key, rhs in list(comp.shapes.items()):
        if not key.startswith("__line__"):
            continue
        lhs = key[len("__line__"):]
        out_shape_text = rhs.split("=", 0)
        # rhs looks like: "f32[a,b]{...} dot(%x, %y), lhs_contracting_dims={1} ..."
        head = rhs
        op_m = re.search(r"\)?\s*([a-z][\w\-]*)\(", head)
        shape_prefix = head.split(" ", 1)[0]
        out_bytes = parse_shape_bytes(shape_prefix if "[" in shape_prefix else head)
        opname = op_m.group(1) if op_m else ""
        # operand names
        operand_names = re.findall(r"%([\w\.\-]+)", head[head.find("(") :] if "(" in head else "")
        operand_bytes = 0
        for on in operand_names:
            sh = comp.shapes.get(on)
            if sh and "[" in sh:
                operand_bytes += parse_shape_bytes(sh)
        if opname in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast"):
            continue
        if opname in ("dynamic-slice", "slice"):
            # reads only the sliced window, not the whole operand (XLA's
            # cost model makes the same correction)
            b = 2.0 * out_bytes
            comp.bytes += b
            comp.bytes_fused += b
        elif opname == "dynamic-update-slice":
            # reads + writes only the updated window
            upd = operand_names[1] if len(operand_names) > 1 else None
            sh = comp.shapes.get(upd) if upd else None
            ub = parse_shape_bytes(sh) if sh and "[" in sh else out_bytes
            comp.bytes += 2.0 * ub
            comp.bytes_fused += 2.0 * ub
        else:
            comp.bytes += out_bytes + operand_bytes
            if opname not in _ELEMENTWISE:
                # TPU projection: the CPU pipeline leaves elementwise chains
                # unfused; on TPU they fuse into producers, so only
                # fusion/dot/copy/reduce/collective traffic counts
                comp.bytes_fused += out_bytes + operand_bytes
        # collectives
        for kind in _COLLECTIVES:
            if opname == kind:
                comp.coll[kind] += out_bytes
                comp.coll_count += 1
        # FLOPs: dot
        if opname == "dot":
            mcon = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", head)
            lhs_name = operand_names[0] if operand_names else None
            k = 1
            if mcon and lhs_name and comp.shapes.get(lhs_name):
                sd = _shape_dims(comp.shapes[lhs_name])
                if sd:
                    dims = sd[1]
                    for ci in mcon.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
            out_sd = _shape_dims(shape_prefix if "[" in shape_prefix else head)
            out_n = 1
            if out_sd:
                for d in out_sd[1]:
                    out_n *= d
            comp.flops += 2.0 * out_n * k
        elif opname == "convolution":
            out_sd = _shape_dims(shape_prefix if "[" in shape_prefix else head)
            if out_sd:
                out_n = 1
                for d in out_sd[1]:
                    out_n *= d
                comp.flops += 2.0 * out_n  # lower bound; convs are rare here
        # call edges
        mw = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", head)
        if not mw:
            mw = re.search(r"body=%?([\w\.\-]+),\s*condition=%?([\w\.\-]+)", head)
            if mw:
                mw = type("m", (), {"group": lambda self, i, a=mw: a.group(2) if i == 1 else a.group(1)})()
        if mw:
            comp.calls.append((mw.group(2), ("while", mw.group(1))))
        for mc in re.finditer(r"(?:calls|to_apply|branch_computations)=\{?%?([\w\.\-]+)", head):
            comp.calls.append((mc.group(1), ("call", None)))


def analyze_hlo(hlo: str) -> dict:
    comps = _split(hlo)
    for c in comps.values():
        _analyze_comp(c)
    trips = _trip_counts(comps)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry not in comps:
        # fall back: first computation
        entry = next(iter(comps), None)
    memo = {}

    def total(name, depth=0):
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_count": 0}
        c = comps[name]
        agg = {
            "flops": c.flops,
            "bytes": c.bytes,
            "bytes_fused": c.bytes_fused,
            "coll": dict(c.coll),
            "coll_count": c.coll_count,
        }
        for callee, (kind, cond) in c.calls:
            mult = trips.get(cond, 1) if kind == "while" else 1
            sub = total(callee, depth + 1)
            agg["flops"] += sub["flops"] * mult
            agg["bytes"] += sub["bytes"] * mult
            agg["bytes_fused"] += sub["bytes_fused"] * mult
            agg["coll_count"] += sub["coll_count"] * mult
            for k, v in sub["coll"].items():
                agg["coll"][k] = agg["coll"].get(k, 0.0) + v * mult
        memo[name] = agg
        return agg

    res = (
        total(entry)
        if entry
        else {"flops": 0, "bytes": 0, "bytes_fused": 0, "coll": {}, "coll_count": 0}
    )
    return {
        "flops": float(res["flops"]),
        "bytes": float(res["bytes"]),
        "bytes_fused": float(res["bytes_fused"]),
        "collective_bytes": {k: float(v) for k, v in res["coll"].items()},
        "collective_bytes_total": float(sum(res["coll"].values())),
        "collective_count": int(res["coll_count"]),
        "n_computations": len(comps),
        "while_trip_counts": trips,
    }
