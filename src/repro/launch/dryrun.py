import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage:"""  # noqa: E501 — real docstring continues below (XLA_FLAGS must be first)
_DOC = """
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]

Per cell, records to JSON:
  * compile success, wall-clock compile time
  * compiled.memory_analysis()  (bytes per device — proves it fits)
  * compiled.cost_analysis()    (HLO FLOPs + bytes for §Roofline)
  * collective bytes parsed from the optimized HLO (launch/hlo_stats)

The orchestrator (--all) runs one subprocess per cell so a pathological
compile can't take the whole sweep down, and already-done cells are
skipped (resumable).
"""

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import (
    build_step,
    get_arch,
    init_params,
    input_pspecs,
    input_specs,
    param_pspecs,
    resolve_config,
)
from ..dist.context import use_mesh
from ..dist.sharding import to_shardings
from ..train.optimizer import OptConfig
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh

OUT_DEFAULT = Path("experiments/dryrun")


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def run_cell(arch_name: str, shape_name: str, mesh_kind: str, out_dir: Path, smoke: bool = False) -> dict:
    arch = get_arch(arch_name)
    cell = arch.cell(shape_name)
    if cell.skip:
        rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind, "status": "skipped", "reason": cell.skip}
        _save(out_dir, rec)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = resolve_config(arch, cell, smoke=smoke)
    t0 = time.time()
    with use_mesh(mesh):
        specs = input_specs(arch, cell, cfg, smoke=smoke)
        pspecs_in = input_pspecs(arch, cell, cfg)
        step, takes_opt = build_step(arch, cell, cfg, mesh=mesh, opt_cfg=OptConfig())
        # abstract params (no allocation)
        params_shape = jax.eval_shape(lambda: init_params(arch, cfg, jax.random.PRNGKey(0)))
        p_pspecs = param_pspecs(arch, cfg, params_shape)
        p_shard = to_shardings(mesh, p_pspecs)
        b_shard = to_shardings(mesh, pspecs_in)
        if takes_opt:
            opt_shape = jax.eval_shape(
                lambda: {
                    "m": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params_shape),
                    "v": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params_shape),
                    "step": jnp.zeros((), jnp.int32),
                }
            )
            o_shard = {
                "m": jax.tree.map(lambda s: s, p_shard),
                "v": jax.tree.map(lambda s: s, p_shard),
                "step": to_shardings(mesh, P()),
            }
            # donate params + opt state → in-place update (halves peak memory)
            fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard), donate_argnums=(0, 1))
            lowered = fn.lower(params_shape, opt_shape, _abstract_tree(specs))
        else:
            donate = (1,) if cell.kind == "decode" else ()  # in-place KV cache
            fn = jax.jit(step, in_shardings=(p_shard, b_shard), donate_argnums=donate)
            lowered = fn.lower(params_shape, _abstract_tree(specs))
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        stats = analyze_hlo(hlo)
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_devices": int(mesh.devices.size),
        "memory": _mem_dict(mem),
        # per-device quantities from the loop-aware HLO analyzer
        "flops": stats["flops"],
        "bytes": stats["bytes"],
        "bytes_fused": stats["bytes_fused"],
        "collective_bytes": stats["collective_bytes"],
        "collective_bytes_total": stats["collective_bytes_total"],
        "collective_count": stats["collective_count"],
        # raw XLA numbers (counts while bodies once — kept for reference)
        "xla_flops_raw": float(cost.get("flops", -1.0)) if cost else -1.0,
        "xla_bytes_raw": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        "model_params": _params_count(cfg, arch),
        "model_params_active": _params_active(cfg, arch),
        "hlo_bytes": len(hlo),
    }
    _save(out_dir, rec)
    return rec


def _abstract_tree(specs):
    return specs  # already ShapeDtypeStructs


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in (
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _params_count(cfg, arch):
    try:
        if arch.family == "lm":
            return int(cfg.n_params())
        import jax

        shapes = jax.eval_shape(lambda: init_params(arch, cfg, jax.random.PRNGKey(0)))
        return int(sum(int(np_prod(x.shape)) for x in jax.tree.leaves(shapes)))
    except Exception:
        return -1


def np_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out


def _params_active(cfg, arch):
    try:
        if arch.family == "lm":
            return int(cfg.n_active_params())
        return _params_count(cfg, arch)
    except Exception:
        return -1


def _save(out_dir: Path, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    p = out_dir / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    p.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] {rec['arch']}/{rec['shape']}/{rec['mesh']}: {rec['status']}", flush=True)


def orchestrate(mesh_kinds: list[str], out_dir: Path, only_arch: str | None = None, timeout: int = 3600):
    from ..configs import all_cells

    cells = all_cells(include_skipped=True, include_extra=True)
    results = []
    for arch, cell in cells:
        if only_arch and arch.name != only_arch:
            continue
        for mk in mesh_kinds:
            p = out_dir / f"{arch.name}__{cell.name}__{mk}.json"
            if p.exists():
                rec = json.loads(p.read_text())
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[dryrun] cached {p.name}: {rec['status']}")
                    results.append(rec)
                    continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch.name, "--shape", cell.name, "--mesh", mk,
                "--out", str(out_dir),
            ]
            t0 = time.time()
            try:
                proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
                if proc.returncode != 0:
                    rec = {
                        "arch": arch.name, "shape": cell.name, "mesh": mk,
                        "status": "error", "stderr": proc.stderr[-4000:],
                        "elapsed_s": round(time.time() - t0, 1),
                    }
                    _save(out_dir, rec)
                else:
                    rec = json.loads(p.read_text())
            except subprocess.TimeoutExpired:
                rec = {"arch": arch.name, "shape": cell.name, "mesh": mk, "status": "timeout"}
                _save(out_dir, rec)
            results.append(rec)
    ok = sum(1 for r in results if r.get("status") == "ok")
    sk = sum(1 for r in results if r.get("status") == "skipped")
    bad = [r for r in results if r.get("status") not in ("ok", "skipped")]
    print(f"[dryrun] done: {ok} ok, {sk} skipped, {len(bad)} failed")
    for r in bad:
        print("  FAILED:", r["arch"], r["shape"], r["mesh"], r.get("status"))
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(OUT_DEFAULT))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    out_dir = Path(args.out)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        orchestrate(mesh_kinds, out_dir, only_arch=args.arch, timeout=args.timeout)
    else:
        assert args.arch and args.shape, "--arch and --shape required without --all"
        for mk in mesh_kinds:
            run_cell(args.arch, args.shape, mk, out_dir, smoke=args.smoke)


if __name__ == "__main__":
    main()
