"""Production mesh builders (assignment spec).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices exist (tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
